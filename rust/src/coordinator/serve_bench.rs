//! `repro slam` — load generator + acceptance harness for the serving
//! runtime (`runtime::server`).
//!
//! One slam run drives the same request set through the async session
//! several ways and cross-checks every response against a synchronous
//! window=1 reference:
//!
//! * **interleaving permutations** — concurrent clients submitting their
//!   id slices forward and reversed, plus a closed-loop run: responses
//!   must be bit-identical (same `(next_byte, fingerprint)` per id) in
//!   every case, because window membership is a function of ids and rows
//!   are compute-independent;
//! * **thread counts** — the coalesced run repeated on a 1-lane
//!   execution context must reproduce the same bits;
//! * **throughput** — open-loop wall time of window=W coalescing vs
//!   window=1 single-row serving over identical requests, reported as
//!   the `coalesce_vs_single` ratio (target ≥ 1.2×, recorded in the
//!   gate; a hard failure only below the clear-regression floor 0.9 so a
//!   noisy CI box can't flake the build);
//! * **memory** — the serving session's memtrack evidence
//!   ([`ServeStats::steady_state_allocs`]) plus an in-process
//!   steady-state probe on the synchronous core: zero tracked
//!   allocations per request after warmup, hard gate.
//!
//! Results land in `BENCH_serve.json` (schema `bench_serve/v1`, reader:
//! `runtime::json`): per-mode records carrying p50/p99 latency,
//! tokens/sec and wall time, plus the named gates.

use crate::autograd::layers::Backend;
use crate::autograd::stack::{SpectralStack, StackConfig};
use crate::autograd::train::Method;
use crate::memtrack;
use crate::runtime::server::{
    spawn_session, ServeRequest, ServeResponse, ServeStats, SpectralServer, Ticket,
};
use anyhow::{ensure, Context, Result};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Configuration of one `repro slam` run.
#[derive(Debug, Clone)]
pub struct SlamConfig {
    /// Model geometry (circulant rdFFT blocks throughout — the serve
    /// path's target configuration).
    pub d: usize,
    pub depth: usize,
    pub p: usize,
    pub ctx: usize,
    pub seed: u64,
    /// Total requests per run (ids 0..requests, dense).
    pub requests: usize,
    /// Coalescing window = tile height of the coalesced mode.
    pub window: usize,
    /// Concurrent client threads submitting load.
    pub clients: usize,
    /// Execution-context lanes for the engine (0 = the global context).
    pub threads: usize,
    /// Timing rounds per mode; wall time is the best round (latencies
    /// come from that round too).
    pub rounds: usize,
    /// Where to write the bench JSON (None = don't write).
    pub bench_json: Option<PathBuf>,
    /// Optional hard latency gate on the coalesced run's p99.
    pub max_p99_ms: Option<f64>,
}

impl Default for SlamConfig {
    fn default() -> Self {
        SlamConfig {
            d: 64,
            depth: 2,
            p: 16,
            ctx: 8,
            seed: 0,
            requests: 512,
            window: 8,
            clients: 4,
            threads: 0,
            rounds: 3,
            bench_json: Some(PathBuf::from("BENCH_serve.json")),
            max_p99_ms: None,
        }
    }
}

/// One measured serving mode, serialized into `BENCH_serve.json`.
#[derive(Debug, Clone)]
pub struct ServeRecord {
    /// `"coalesced"`, `"single"`, or `"closed_loop"`.
    pub mode: String,
    pub window: usize,
    pub clients: usize,
    pub threads: usize,
    pub requests: usize,
    /// Submit→serve latency percentiles (measured on the serve thread).
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Next-byte predictions per second over the best round's wall time.
    pub tokens_per_sec: f64,
    pub wall_ms: f64,
}

/// One acceptance gate, serialized next to the records.
#[derive(Debug, Clone)]
pub struct ServeGate {
    pub name: String,
    /// Measured value (ratio, count, or milliseconds — per gate).
    pub ratio: f64,
    pub target: f64,
    pub pass: bool,
}

/// Write serve bench records + gates, schema `bench_serve/v1`
/// (hand-rolled like `benchlib::write_bench_json`; reader:
/// `runtime::json`).
pub fn write_serve_json(
    path: &std::path::Path,
    records: &[ServeRecord],
    gates: &[ServeGate],
) -> std::io::Result<()> {
    let mut s = String::new();
    s.push_str("{\n  \"schema\": \"bench_serve/v1\",\n  \"records\": [\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"mode\": \"{}\", \"window\": {}, \"clients\": {}, \"threads\": {}, \
             \"requests\": {}, \"p50_ms\": {:.4}, \"p99_ms\": {:.4}, \
             \"tokens_per_sec\": {:.1}, \"wall_ms\": {:.2}}}{}\n",
            r.mode,
            r.window,
            r.clients,
            r.threads,
            r.requests,
            r.p50_ms,
            r.p99_ms,
            r.tokens_per_sec,
            r.wall_ms,
            if i + 1 == records.len() { "" } else { "," },
        ));
    }
    s.push_str("  ],\n  \"gates\": [\n");
    for (i, g) in gates.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"ratio\": {:.4}, \"target\": {:.4}, \"pass\": {}}}{}\n",
            g.name,
            g.ratio,
            g.target,
            g.pass,
            if i + 1 == gates.len() { "" } else { "," },
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s)
}

fn stack_config(cfg: &SlamConfig) -> StackConfig {
    StackConfig {
        d: cfg.d,
        depth: cfg.depth,
        ctx: cfg.ctx,
        method: Method::Circulant { backend: Backend::RdFft, p: cfg.p },
        seed: cfg.seed,
        ..Default::default()
    }
}

fn build_stack(cfg: &SlamConfig, threads: usize) -> SpectralStack {
    let exec = if threads == 0 {
        crate::runtime::pool::ExecCtx::global()
    } else {
        crate::runtime::pool::ExecCtx::with_threads(threads)
    };
    SpectralStack::with_exec(stack_config(cfg), exec)
}

/// The deterministic request set: sliding `ctx`-byte windows over a
/// seeded corpus, one per request id.
fn gen_requests(cfg: &SlamConfig) -> Vec<Vec<u8>> {
    let text = crate::data::CorpusGen::new(cfg.seed).text(cfg.requests + cfg.ctx);
    let bytes = text.as_bytes();
    (0..cfg.requests).map(|i| bytes[i..i + cfg.ctx].to_vec()).collect()
}

/// Client submission order within its id slice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SubmitOrder {
    Forward,
    Reverse,
}

/// Outcome of one async run over the full request set.
struct RunOutcome {
    /// Responses sorted by id (exactly `requests` of them).
    responses: Vec<ServeResponse>,
    /// Per-request submit→serve latencies (ns), unordered.
    latencies_ns: Vec<u64>,
    wall: Duration,
    stats: ServeStats,
}

/// Open-loop run: `clients` threads submit strided id slices (client j
/// owns ids j, j+C, ...), the main thread flushes the final partial
/// window once every submission landed, then reaps all tickets.
fn run_open_loop(
    cfg: &SlamConfig,
    window: usize,
    threads: usize,
    order: SubmitOrder,
    reqs: &Arc<Vec<Vec<u8>>>,
) -> Result<RunOutcome> {
    let scfg = cfg.clone();
    let (handle, session) = spawn_session(move || build_stack(&scfg, threads), window)
        .context("starting serve session")?;
    let n = reqs.len();
    let clients = cfg.clients.max(1);
    let t0 = Instant::now();
    let mut submitters = Vec::new();
    for c in 0..clients {
        let h = handle.clone();
        let reqs = Arc::clone(reqs);
        // audit: allow(no-raw-threads) load-generator clients must be real concurrent submitters outside the pool they measure
        submitters.push(std::thread::spawn(move || {
            let mut ids: Vec<usize> = (c..reqs.len()).step_by(clients).collect();
            if order == SubmitOrder::Reverse {
                ids.reverse();
            }
            ids.into_iter()
                .map(|id| (id as u64, h.submit(id as u64, reqs[id].clone())))
                .collect::<Vec<(u64, Ticket)>>()
        }));
    }
    let mut tickets: Vec<(u64, Ticket)> = Vec::with_capacity(n);
    for s in submitters {
        tickets.extend(s.join().expect("submitter panicked"));
    }
    // All ids are in the queue; close the final partial window.
    handle.flush();
    let mut responses = Vec::with_capacity(n);
    let mut latencies_ns = Vec::with_capacity(n);
    for (_, t) in tickets {
        let (resp, lat) = t.wait();
        responses.push(resp);
        latencies_ns.push(lat);
    }
    let wall = t0.elapsed();
    let stats = session.shutdown();
    responses.sort_by_key(|r| r.id);
    Ok(RunOutcome { responses, latencies_ns, wall, stats })
}

/// Closed-loop run: every client keeps exactly one request in flight.
/// Requires `clients >= window` so complete tiles keep forming mid-run.
///
/// Ids here are **admission-order** (`submit_next`), not the request
/// indices: a closed loop interleaves submission with serving, and a
/// pre-assigned strided id could race the serve cursor when a periodic
/// flush drains a partial tile. `submit_next` assigns the id and
/// enqueues the entry in a single queue-lock critical section, so the
/// cursor can never pass an assigned-but-unqueued id and any flush
/// timing is safe. Responses are therefore matched back to requests by *content*
/// (each worker pairs its own submissions), and the returned responses
/// carry the request index as `id` so the bit-identity comparison
/// against the reference still lines up — legitimate, because a
/// response is a pure function of the request bytes, never of the id.
fn run_closed_loop(
    cfg: &SlamConfig,
    window: usize,
    threads: usize,
    reqs: &Arc<Vec<Vec<u8>>>,
) -> Result<RunOutcome> {
    ensure!(cfg.clients >= window, "closed loop needs clients >= window");
    let scfg = cfg.clone();
    let (handle, session) = spawn_session(move || build_stack(&scfg, threads), window)
        .context("starting serve session")?;
    let n = reqs.len();
    let clients = cfg.clients;
    let t0 = Instant::now();
    let mut workers = Vec::new();
    for c in 0..clients {
        let h = handle.clone();
        let reqs = Arc::clone(reqs);
        // audit: allow(no-raw-threads) closed-loop clients must be real concurrent submitters outside the pool they measure
        workers.push(std::thread::spawn(move || {
            let mut out = Vec::new();
            for i in (c..reqs.len()).step_by(clients) {
                let t = h.submit_next(reqs[i].clone());
                let (resp, lat) = t.wait();
                out.push((i, resp, lat));
            }
            out
        }));
    }
    // The tail (fewer outstanding requests than a full tile) can only
    // drain via flush; a periodic flush is harmless earlier — it changes
    // batching, never results.
    let flusher_handle = handle.clone();
    let stop_flusher = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let stop = Arc::clone(&stop_flusher);
    // audit: allow(no-raw-threads) the periodic flusher is harness plumbing racing the batcher on purpose; it never computes
    let flusher = std::thread::spawn(move || {
        while !stop.load(std::sync::atomic::Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(2));
            flusher_handle.flush();
        }
    });
    let mut responses = Vec::with_capacity(n);
    let mut latencies_ns = Vec::with_capacity(n);
    for w in workers {
        for (i, resp, lat) in w.join().expect("client panicked") {
            responses.push(ServeResponse { id: i as u64, ..resp });
            latencies_ns.push(lat);
        }
    }
    stop_flusher.store(true, std::sync::atomic::Ordering::Relaxed);
    flusher.join().expect("flusher panicked");
    let wall = t0.elapsed();
    let stats = session.shutdown();
    responses.sort_by_key(|r| r.id);
    Ok(RunOutcome { responses, latencies_ns, wall, stats })
}

fn percentile_ms(latencies_ns: &mut [u64], p: f64) -> f64 {
    assert!(!latencies_ns.is_empty());
    latencies_ns.sort_unstable();
    let i = ((latencies_ns.len() as f64 - 1.0) * p) as usize;
    latencies_ns[i] as f64 / 1e6
}

fn record_from(mode: &str, cfg: &SlamConfig, window: usize, out: &mut RunOutcome) -> ServeRecord {
    ServeRecord {
        mode: mode.to_string(),
        window,
        clients: cfg.clients,
        threads: cfg.threads,
        requests: out.responses.len(),
        p50_ms: percentile_ms(&mut out.latencies_ns, 0.5),
        p99_ms: percentile_ms(&mut out.latencies_ns, 0.99),
        tokens_per_sec: out.responses.len() as f64 / out.wall.as_secs_f64().max(1e-9),
        wall_ms: out.wall.as_secs_f64() * 1e3,
    }
}

/// Compare a run's responses against the reference; returns the number
/// of ids whose bits differ (0 = bit-identical).
fn diff_count(reference: &[ServeResponse], got: &[ServeResponse]) -> usize {
    if reference.len() != got.len() {
        return reference.len().max(got.len());
    }
    reference.iter().zip(got).filter(|(a, b)| a != b).count()
}

/// Run the full slam harness. Returns `true` when every hard gate holds
/// (determinism, completeness, zero steady-state allocation, the
/// clear-regression throughput floor, and — when configured — the p99
/// budget); the ≥ 1.2× coalescing target itself is recorded in the JSON
/// but only advisory, like the engine bench's noisy-box policy.
pub fn slam(cfg: &SlamConfig) -> Result<bool> {
    ensure!(cfg.window > 0, "--window must be at least 1");
    ensure!(cfg.requests > 0, "--requests must be at least 1");
    ensure!(cfg.d % cfg.p == 0, "--d {} must be a multiple of --p {}", cfg.d, cfg.p);
    println!(
        "[slam] d={} depth={} p={} ctx={} window={} clients={} threads={} requests={}",
        cfg.d, cfg.depth, cfg.p, cfg.ctx, cfg.window, cfg.clients, cfg.threads, cfg.requests
    );
    let reqs = Arc::new(gen_requests(cfg));

    // ---- reference: synchronous single-row serving on this thread ----
    let mut reference = Vec::with_capacity(reqs.len());
    let mut sync_steady_allocs = 0usize;
    {
        let mut server = SpectralServer::new(build_stack(cfg, cfg.threads), 1)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut out = Vec::with_capacity(1);
        for (i, ctx) in reqs.iter().enumerate() {
            let req = ServeRequest { id: i as u64, ctx: ctx.clone() };
            if i == 1 {
                // Warmup done after request 0; everything from here on
                // must be allocation-free on the tracked side.
                let before = memtrack::snapshot().alloc_count;
                out.clear();
                server.serve_window(std::slice::from_ref(&req), &mut out);
                sync_steady_allocs = memtrack::snapshot().alloc_count - before;
            } else {
                out.clear();
                server.serve_window(std::slice::from_ref(&req), &mut out);
            }
            reference.push(out[0]);
        }
    }

    // ---- determinism: interleavings and thread counts ----
    let mut mismatches = 0usize;
    let mut complete = true;
    let rev = run_open_loop(cfg, cfg.window, cfg.threads, SubmitOrder::Reverse, &reqs)?;
    mismatches += diff_count(&reference, &rev.responses);
    complete &= rev.stats.served as usize == reqs.len();
    let one_lane = run_open_loop(cfg, cfg.window, 1, SubmitOrder::Forward, &reqs)?;
    mismatches += diff_count(&reference, &one_lane.responses);
    complete &= one_lane.stats.served as usize == reqs.len();
    println!(
        "[slam] determinism: reverse-arrival + 1-lane runs vs reference → {} mismatching \
         responses ({} requests each)",
        mismatches,
        reqs.len()
    );

    // ---- throughput: coalesced (window=W) vs single (window=1) ----
    let mut best_by_mode: Vec<(String, usize, RunOutcome)> = Vec::new();
    for (mode, window) in [("coalesced", cfg.window), ("single", 1usize)] {
        let mut best: Option<RunOutcome> = None;
        for _ in 0..cfg.rounds.max(1) {
            let out = run_open_loop(cfg, window, cfg.threads, SubmitOrder::Forward, &reqs)?;
            mismatches += diff_count(&reference, &out.responses);
            complete &= out.stats.served as usize == reqs.len();
            if best.as_ref().map_or(true, |b| out.wall < b.wall) {
                best = Some(out);
            }
        }
        best_by_mode.push((mode.to_string(), window, best.expect("rounds >= 1")));
    }

    let mut records = Vec::new();
    let mut async_steady_allocs = 0usize;
    for (mode, window, out) in best_by_mode.iter_mut() {
        async_steady_allocs += out.stats.steady_state_allocs;
        let rec = record_from(mode, cfg, *window, out);
        println!(
            "[slam] {:<10} window={:<3} p50 {:.3} ms  p99 {:.3} ms  {:.0} tok/s  \
             (wall {:.1} ms, arena {} B)",
            rec.mode, rec.window, rec.p50_ms, rec.p99_ms, rec.tokens_per_sec, rec.wall_ms,
            out.stats.serve_bytes,
        );
        records.push(rec);
    }

    // ---- closed loop (only when every window can fill: clients >= W) ----
    if cfg.clients >= cfg.window {
        let mut out = run_closed_loop(cfg, cfg.window, cfg.threads, &reqs)?;
        mismatches += diff_count(&reference, &out.responses);
        complete &= out.stats.served as usize == reqs.len();
        async_steady_allocs += out.stats.steady_state_allocs;
        let rec = record_from("closed_loop", cfg, cfg.window, &mut out);
        println!(
            "[slam] {:<10} window={:<3} p50 {:.3} ms  p99 {:.3} ms  {:.0} tok/s",
            rec.mode, rec.window, rec.p50_ms, rec.p99_ms, rec.tokens_per_sec
        );
        records.push(rec);
    } else {
        println!(
            "[slam] closed loop skipped: clients {} < window {} cannot fill a tile",
            cfg.clients, cfg.window
        );
    }

    // ---- gates ----
    let tps = |mode: &str| {
        records
            .iter()
            .find(|r| r.mode == mode)
            .map(|r| r.tokens_per_sec)
            .unwrap_or(0.0)
    };
    let ratio = tps("coalesced") / tps("single").max(1e-9);
    let steady = sync_steady_allocs + async_steady_allocs;
    let coalesced_p99 = records.iter().find(|r| r.mode == "coalesced").map(|r| r.p99_ms);
    let mut gates = vec![
        ServeGate {
            name: "coalesce_vs_single".into(),
            ratio,
            target: 1.2,
            pass: ratio >= 1.2,
        },
        ServeGate {
            name: "responses_complete".into(),
            ratio: if complete { 1.0 } else { 0.0 },
            target: 1.0,
            pass: complete,
        },
        ServeGate {
            name: "determinism_bit_identical".into(),
            ratio: mismatches as f64,
            target: 0.0,
            pass: mismatches == 0,
        },
        ServeGate {
            name: "zero_steady_state_alloc".into(),
            ratio: steady as f64,
            target: 0.0,
            pass: steady == 0,
        },
    ];
    if let (Some(budget), Some(p99)) = (cfg.max_p99_ms, coalesced_p99) {
        gates.push(ServeGate {
            name: "p99_under_budget".into(),
            ratio: p99,
            target: budget,
            pass: p99 <= budget,
        });
    }
    for g in &gates {
        println!(
            "[slam] gate {:<26} measured {:>10.4} target {:>8.4}  {}",
            g.name,
            g.ratio,
            g.target,
            if g.pass { "PASS" } else { "MISS" }
        );
    }

    if let Some(path) = &cfg.bench_json {
        write_serve_json(path, &records, &gates)
            .with_context(|| format!("writing {}", path.display()))?;
        println!("[slam] wrote {}", path.display());
    }

    // Hard verdict: correctness gates always; the throughput target only
    // below the clear-regression floor (coalescing must never be *slower*
    // than single-row by more than noise).
    let hard_floor = 0.9;
    let hard_pass = complete
        && mismatches == 0
        && steady == 0
        && ratio >= hard_floor
        && cfg
            .max_p99_ms
            .map_or(true, |b| coalesced_p99.map_or(false, |p| p <= b));
    if ratio < hard_floor {
        println!("[slam] HARD FAIL: coalescing ratio {ratio:.3} below floor {hard_floor}");
    }
    Ok(hard_pass)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_json_roundtrips_through_parser() {
        let rec = ServeRecord {
            mode: "coalesced".into(),
            window: 8,
            clients: 4,
            threads: 2,
            requests: 512,
            p50_ms: 0.42,
            p99_ms: 1.75,
            tokens_per_sec: 12345.6,
            wall_ms: 41.5,
        };
        let gate = ServeGate {
            name: "coalesce_vs_single".into(),
            ratio: 1.44,
            target: 1.2,
            pass: true,
        };
        let dir = std::env::temp_dir().join(format!("rdfft_servejson_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_serve.json");
        write_serve_json(&path, &[rec.clone(), rec], &[gate]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::runtime::json::parse(&text).expect("valid json");
        assert_eq!(v.get("schema").unwrap().as_str(), Some("bench_serve/v1"));
        let recs = v.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].get("mode").unwrap().as_str(), Some("coalesced"));
        assert_eq!(recs[0].get("window").unwrap().as_usize(), Some(8));
        assert_eq!(recs[0].get("requests").unwrap().as_usize(), Some(512));
        assert!((recs[0].get("p99_ms").unwrap().as_f64().unwrap() - 1.75).abs() < 1e-9);
        let gates = v.get("gates").unwrap().as_arr().unwrap();
        assert_eq!(gates[0].get("name").unwrap().as_str(), Some("coalesce_vs_single"));
        assert_eq!(gates[0].get("pass").unwrap().as_bool(), Some(true));
        assert!((gates[0].get("ratio").unwrap().as_f64().unwrap() - 1.44).abs() < 1e-9);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn request_generation_is_deterministic_and_sized() {
        let cfg = SlamConfig { requests: 32, ctx: 8, ..Default::default() };
        let a = gen_requests(&cfg);
        let b = gen_requests(&cfg);
        assert_eq!(a, b);
        assert_eq!(a.len(), 32);
        assert!(a.iter().all(|r| r.len() == 8));
        // Sliding windows: consecutive requests overlap by ctx-1 bytes.
        assert_eq!(a[0][1..], a[1][..7]);
    }
}
