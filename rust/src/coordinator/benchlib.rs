//! Tiny benchmarking harness (criterion is unavailable offline; this
//! provides the subset the tables need: warmup, calibrated iteration
//! counts, and robust statistics).

use std::time::Instant;

/// Robust timing statistics over many runs of a closure.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters: usize,
}

impl Stats {
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }
    pub fn median_us(&self) -> f64 {
        self.median_ns / 1e3
    }
}

/// Run `f` repeatedly for roughly `budget_ms` milliseconds (after a
/// warmup) and report per-iteration statistics. `f` should include any
/// per-iteration state reset.
///
/// The first call doubles as the warmup probe: when a single call already
/// exceeds the warmup window (ultra-slow closures — large-n throughput
/// cells), warmup is capped at that one iteration instead of duplicating
/// nearly the whole budget, so slow cells finish within budget. The
/// sampling loop always records at least one sample.
pub fn bench<F: FnMut()>(budget_ms: u64, mut f: F) -> Stats {
    let warm_window = std::time::Duration::from_millis(budget_ms / 5 + 1);
    let t0 = Instant::now();
    f();
    let mut single = t0.elapsed();
    if single < warm_window {
        let warm_until = Instant::now() + (warm_window - single);
        while Instant::now() < warm_until {
            f();
        }
        // Re-probe now that caches/pages are warm: the cold first call
        // would otherwise mis-calibrate fast closures into tiny batches.
        let t1 = Instant::now();
        f();
        single = t1.elapsed();
    }
    // calibrate batch size so one sample is >= ~20us
    let single_ns = single.as_nanos().max(1) as u64;
    let batch = (20_000 / single_ns).max(1) as usize;

    let mut samples = Vec::new();
    let until = Instant::now() + std::time::Duration::from_millis(budget_ms);
    loop {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / batch as f64);
        if Instant::now() >= until {
            break;
        }
    }
    stats_from(samples)
}

/// [`bench`] for closures whose single call is itself expensive (the
/// large-n four-step throughput cells: one 262 Ki-point batch roundtrip
/// is milliseconds, not microseconds): one untimed probe call warms
/// plans, pool threads and page tables, then single-call samples are
/// taken until the wall-clock budget expires — no batch calibration, no
/// warmup window proportional to the budget. Always records at least one
/// sample, so a closure slower than the whole budget still yields a
/// (single-sample) measurement instead of hanging.
pub fn bench_budgeted<F: FnMut()>(budget_ms: u64, mut f: F) -> Stats {
    f(); // untimed warm probe
    let until = Instant::now() + std::time::Duration::from_millis(budget_ms);
    let mut samples = Vec::new();
    loop {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if Instant::now() >= until {
            break;
        }
    }
    stats_from(samples)
}

fn stats_from(mut samples: Vec<f64>) -> Stats {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let q = |p: f64| samples[((n as f64 - 1.0) * p) as usize];
    Stats { mean_ns: mean, median_ns: q(0.5), p10_ns: q(0.1), p90_ns: q(0.9), iters: n }
}

/// One measured cell of the rdFFT engine benchmark grid, serialized into
/// `BENCH_rdfft.json` (schema documented in EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Execution mode: `"scalar"`, `"batch_major"`, `"batch_threads"`,
    /// `"circulant_unfused"`, `"circulant_fused"`, or the pool grid's
    /// `"batch_scoped"` / `"batch_pool"` / `"circulant_fused_scoped"` /
    /// `"circulant_fused_pool"`.
    pub mode: String,
    /// Transform size.
    pub n: usize,
    /// Rows per call.
    pub batch: usize,
    /// Thread lanes the mode was pinned to (`0` = auto / not pinned —
    /// the pre-pool modes).
    pub threads: usize,
    /// Stats over the timed closure (one fwd+inv roundtrip of the batch).
    pub stats: Stats,
    /// Transforms per second: `2 * batch / median_seconds`.
    pub transforms_per_sec: f64,
    /// Throughput relative to the scalar row loop at the same (n, batch).
    /// `circulant_fused` rows carry fused-vs-unfused; `*_pool` rows carry
    /// pool-vs-scoped at the same thread count.
    pub speedup_vs_scalar: f64,
}

/// One acceptance gate evaluated by the engine bench, serialized next to
/// the records so CI (and the PR driver) can read pass/fail without
/// re-parsing the grid.
#[derive(Debug, Clone)]
pub struct BenchGate {
    /// e.g. `"pool_vs_scoped_batch"`.
    pub name: String,
    pub threads: usize,
    pub n: usize,
    pub batch: usize,
    /// Measured ratio (higher is better).
    pub ratio: f64,
    /// Acceptance target for the ratio.
    pub target: f64,
    pub pass: bool,
}

/// Write engine benchmark records + gates as JSON, schema
/// `bench_rdfft/v3` (hand-rolled: serde is unavailable offline; the
/// reader side is `runtime::json`). v3 over v2: the large-n
/// `batch_fourstep` / `batch_direct` rows, the width-8 `batch_simd8` /
/// `batch_simd4` rows, and the `fourstep_vs_direct` / `simd8_vs_simd4`
/// gates (EXPERIMENTS.md §Perf iteration 7); record/gate field layout is
/// unchanged.
pub fn write_bench_json(
    path: &std::path::Path,
    records: &[BenchRecord],
    gates: &[BenchGate],
) -> std::io::Result<()> {
    let mut s = String::new();
    s.push_str("{\n  \"schema\": \"bench_rdfft/v3\",\n  \"records\": [\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"mode\": \"{}\", \"n\": {}, \"batch\": {}, \"threads\": {}, \
             \"median_ns\": {:.1}, \"mean_ns\": {:.1}, \"p10_ns\": {:.1}, \
             \"p90_ns\": {:.1}, \"iters\": {}, \"transforms_per_sec\": {:.1}, \
             \"speedup_vs_scalar\": {:.3}}}{}\n",
            r.mode,
            r.n,
            r.batch,
            r.threads,
            r.stats.median_ns,
            r.stats.mean_ns,
            r.stats.p10_ns,
            r.stats.p90_ns,
            r.stats.iters,
            r.transforms_per_sec,
            r.speedup_vs_scalar,
            if i + 1 == records.len() { "" } else { "," },
        ));
    }
    s.push_str("  ],\n  \"gates\": [\n");
    for (i, g) in gates.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"threads\": {}, \"n\": {}, \"batch\": {}, \
             \"ratio\": {:.3}, \"target\": {:.3}, \"pass\": {}}}{}\n",
            g.name,
            g.threads,
            g.n,
            g.batch,
            g.ratio,
            g.target,
            g.pass,
            if i + 1 == gates.len() { "" } else { "," },
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s)
}

/// Format a byte count like the paper's tables (MB with two decimals).
pub fn fmt_mib(bytes: usize) -> String {
    format!("{:.2}", bytes as f64 / (1024.0 * 1024.0))
}

/// Format a ratio annotation like the paper's "(×7.11)".
pub fn fmt_ratio(base: usize, v: usize) -> String {
    if v == 0 {
        return "(×inf)".into();
    }
    format!("(×{:.2})", base as f64 / v as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let mut x = 0u64;
        let s = bench(30, || {
            for i in 0..100 {
                x = x.wrapping_add(i * i);
            }
            std::hint::black_box(x);
        });
        assert!(s.iters > 0);
        assert!(s.median_ns > 0.0);
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
    }

    #[test]
    fn formatting_matches_paper_style() {
        assert_eq!(fmt_mib(1024 * 1024), "1.00");
        assert_eq!(fmt_ratio(7340032, 1048576), "(×7.00)");
    }

    #[test]
    fn slow_closure_stays_within_budget() {
        // One call takes ~3x the warmup window; the capped warmup must
        // keep the whole bench within ~(1 call warmup + budget + 1 call
        // overshoot) instead of duplicating the budget during warmup.
        let budget_ms = 20u64;
        let t0 = std::time::Instant::now();
        let s = bench(budget_ms, || {
            std::thread::sleep(std::time::Duration::from_millis(12));
        });
        let elapsed = t0.elapsed().as_millis() as u64;
        assert!(s.iters >= 1);
        assert!(
            elapsed < 4 * budget_ms,
            "slow-closure bench blew the budget: {elapsed}ms for budget {budget_ms}ms"
        );
    }

    #[test]
    fn bench_budgeted_respects_wall_clock_and_samples_at_least_once() {
        // A closure slower than the whole budget must still produce one
        // sample and stop right after it.
        let t0 = std::time::Instant::now();
        let s = bench_budgeted(5, || {
            std::thread::sleep(std::time::Duration::from_millis(8));
        });
        assert_eq!(s.iters, 1, "one over-budget sample, then stop");
        assert!(t0.elapsed().as_millis() < 80, "warm probe + one sample only");

        // A fast closure takes many single-call samples within budget.
        let mut x = 0u64;
        let s = bench_budgeted(10, || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(s.iters > 10);
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
    }

    #[test]
    fn bench_json_roundtrips_through_parser() {
        let rec = BenchRecord {
            mode: "batch_pool".into(),
            n: 256,
            batch: 8,
            threads: 4,
            stats: Stats { mean_ns: 10.0, median_ns: 9.0, p10_ns: 8.0, p90_ns: 12.0, iters: 5 },
            transforms_per_sec: 1.6e9,
            speedup_vs_scalar: 2.25,
        };
        let gate = BenchGate {
            name: "pool_vs_scoped_batch".into(),
            threads: 4,
            n: 4096,
            batch: 32,
            ratio: 1.31,
            target: 1.15,
            pass: true,
        };
        let dir = std::env::temp_dir()
            .join(format!("rdfft_benchjson_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_rdfft.json");
        write_bench_json(&path, &[rec.clone(), rec], &[gate]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::runtime::json::parse(&text).expect("valid json");
        assert_eq!(v.get("schema").unwrap().as_str(), Some("bench_rdfft/v3"));
        let recs = v.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].get("n").unwrap().as_usize(), Some(256));
        assert_eq!(recs[0].get("mode").unwrap().as_str(), Some("batch_pool"));
        assert_eq!(recs[0].get("threads").unwrap().as_usize(), Some(4));
        assert!((recs[0].get("speedup_vs_scalar").unwrap().as_f64().unwrap() - 2.25).abs() < 1e-9);
        let gates = v.get("gates").unwrap().as_arr().unwrap();
        assert_eq!(gates.len(), 1);
        assert_eq!(gates[0].get("name").unwrap().as_str(), Some("pool_vs_scoped_batch"));
        assert_eq!(gates[0].get("pass").unwrap().as_bool(), Some(true));
        assert!((gates[0].get("ratio").unwrap().as_f64().unwrap() - 1.31).abs() < 1e-9);
    }
}
