//! Tiny benchmarking harness (criterion is unavailable offline; this
//! provides the subset the tables need: warmup, calibrated iteration
//! counts, and robust statistics).

use std::time::Instant;

/// Robust timing statistics over many runs of a closure.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters: usize,
}

impl Stats {
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }
    pub fn median_us(&self) -> f64 {
        self.median_ns / 1e3
    }
}

/// Run `f` repeatedly for roughly `budget_ms` milliseconds (after a
/// warmup) and report per-iteration statistics. `f` should include any
/// per-iteration state reset; use [`bench_batched`] if the op is too fast
/// to time individually.
pub fn bench<F: FnMut()>(budget_ms: u64, mut f: F) -> Stats {
    // warmup
    let warm_until = Instant::now() + std::time::Duration::from_millis(budget_ms / 5 + 1);
    while Instant::now() < warm_until {
        f();
    }
    // calibrate batch size so one sample is >= ~20us
    let t0 = Instant::now();
    f();
    let single = t0.elapsed().as_nanos().max(1) as u64;
    let batch = (20_000 / single).max(1) as usize;

    let mut samples = Vec::new();
    let until = Instant::now() + std::time::Duration::from_millis(budget_ms);
    while Instant::now() < until {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / batch as f64);
    }
    stats_from(samples)
}

fn stats_from(mut samples: Vec<f64>) -> Stats {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let q = |p: f64| samples[((n as f64 - 1.0) * p) as usize];
    Stats { mean_ns: mean, median_ns: q(0.5), p10_ns: q(0.1), p90_ns: q(0.9), iters: n }
}

/// Format a byte count like the paper's tables (MB with two decimals).
pub fn fmt_mib(bytes: usize) -> String {
    format!("{:.2}", bytes as f64 / (1024.0 * 1024.0))
}

/// Format a ratio annotation like the paper's "(×7.11)".
pub fn fmt_ratio(base: usize, v: usize) -> String {
    if v == 0 {
        return "(×inf)".into();
    }
    format!("(×{:.2})", base as f64 / v as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let mut x = 0u64;
        let s = bench(30, || {
            for i in 0..100 {
                x = x.wrapping_add(i * i);
            }
            std::hint::black_box(x);
        });
        assert!(s.iters > 0);
        assert!(s.median_ns > 0.0);
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
    }

    #[test]
    fn formatting_matches_paper_style() {
        assert_eq!(fmt_mib(1024 * 1024), "1.00");
        assert_eq!(fmt_ratio(7340032, 1048576), "(×7.00)");
    }
}
