//! L3 coordinator: training orchestration and the experiment harness.
//!
//! The paper's contribution lives at the operator level (L1/L2), so the
//! coordinator is the thin-but-real driver the system prompt prescribes:
//! process lifecycle, CLI plumbing (`main.rs`), the end-to-end training
//! loop over the PJRT runtime, metrics, checkpointing — plus one driver
//! per table/figure of the paper's evaluation section:
//!
//! | driver                | paper artifact |
//! |-----------------------|----------------|
//! | [`experiments::table1`] | Table 1 (single-layer peak memory grid) |
//! | [`experiments::fig2`]   | Fig 2 (memory breakdown)                |
//! | [`experiments::table2`] | Table 2 (full-model memory)             |
//! | [`experiments::table3`] | Table 3 (operator runtime + accuracy)   |
//! | [`experiments::table4`] | Table 4 (throughput + task accuracy)    |
//! | [`trainer::Trainer`]    | end-to-end loss-curve run (PJRT/AOT)    |
//! | [`native::NativeTrainer`] | pure-Rust loss-curve + memory run     |
//! | [`serve_bench::slam`]   | serving latency/throughput (BENCH_serve.json) |

pub mod benchlib;
pub mod experiments;
pub mod native;
pub mod serve_bench;
pub mod trainer;

pub use native::{NativeReport, NativeTrainer, NativeTrainerConfig};
pub use serve_bench::{slam, SlamConfig};
pub use trainer::{TrainReport, Trainer, TrainerConfig};

/// Create a metrics CSV with `header` already written — shared by the
/// PJRT and native trainers so both log files parse the same way.
pub(crate) fn open_csv(
    path: &std::path::Path,
    header: &str,
) -> anyhow::Result<std::fs::File> {
    use anyhow::Context;
    use std::io::Write;
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    writeln!(f, "{header}")?;
    Ok(f)
}
