//! Experiment drivers: regenerate every table and figure of the paper.
//!
//! Each driver prints the same rows/series the paper reports. Absolute
//! numbers differ from the A100/A800 testbed (see DESIGN.md §2); the
//! comparisons — who wins, by what factor, where crossovers fall — are
//! the reproduction target (EXPERIMENTS.md records paper-vs-measured).

use crate::autograd::layers::Backend;
use crate::autograd::train::{
    finetune_classifier, measure_single_layer, measure_single_layer_with_state, ClassifyTask,
    Method,
};
use crate::baselines::{self, complex_fft, rfft};
use crate::coordinator::benchlib::{bench, fmt_mib, fmt_ratio};
use crate::memtrack::{Category, CATEGORIES};
use crate::rdfft::{self, plan::cached};

const BACKENDS: [Backend; 3] = [Backend::Fft, Backend::Rfft, Backend::RdFft];

/// Table 1: peak memory (MiB) during single-layer fwd+bwd, over
/// D ∈ {1024, 4096}, B ∈ {1, 16, 256}, methods FF / LoRA / {fft,rfft,ours}
/// × p. `scale` shrinks the grid for quick runs (scale=1 reproduces the
/// paper's full grid; the FF column at D=4096,B=256 is minutes of scalar
/// matmul, so `--fast` uses D ∈ {256, 1024}).
pub fn table1(fast: bool) {
    let (dims, batches, ps): (Vec<usize>, Vec<usize>, Vec<usize>) = if fast {
        (vec![1024, 256], vec![1, 16], vec![128, 256])
    } else {
        (vec![4096, 1024], vec![1, 16, 256], vec![128, 256, 512, 1024, 4096])
    };
    println!("# Table 1 — peak memory (MiB) during single-layer training (fwd+bwd)");
    println!("# rows: method; columns: (D, B); parentheses: reduction vs full fine-tune\n");

    for &d in &dims {
        let mut header = format!("{:<16}", format!("D = {d}"));
        for &b in &batches {
            header.push_str(&format!("{:>22}", format!("B={b}")));
        }
        println!("{header}");

        let mut methods: Vec<Method> = vec![
            Method::FullFinetune,
            Method::Lora { rank: if d >= 4096 { 64 } else { 32 } },
        ];
        for &p in &ps {
            if p <= d {
                for bk in BACKENDS {
                    methods.push(Method::Circulant { backend: bk, p });
                }
            }
        }

        // full fine-tune baselines per batch (for the ratio column)
        let ff: Vec<usize> = batches
            .iter()
            .map(|&b| measure_single_layer_with_state(Method::FullFinetune, d, b, 1).peak_bytes)
            .collect();

        for m in methods {
            let mut row = format!("{:<16}", m.label());
            for (bi, &b) in batches.iter().enumerate() {
                let cell = measure_single_layer_with_state(m, d, b, 1);
                let ratio = if matches!(m, Method::FullFinetune) {
                    String::new()
                } else {
                    fmt_ratio(ff[bi], cell.peak_bytes)
                };
                row.push_str(&format!("{:>22}", format!("{} {}", fmt_mib(cell.peak_bytes), ratio)));
            }
            println!("{row}");
        }
        println!();
    }
}

/// Fig 2: memory breakdown (weights / trainable / gradients /
/// intermediates / other) at the peak moment, D fixed, two batch sizes.
pub fn fig2(d: usize, fast: bool) {
    let batches: &[usize] = if fast { &[1, 16] } else { &[1, 256] };
    let p = (d / 8).max(16);
    println!("# Fig 2 — memory breakdown at peak, single-layer training, D={d}, p={p}");
    for &b in batches {
        println!("\n## batch = {b}");
        println!(
            "{:<16}{:>12}{:>12}{:>12}{:>14}{:>10}{:>12}",
            "method", "weights", "trainable", "grads", "intermediate", "other", "peak(MiB)"
        );
        let methods = [
            Method::FullFinetune,
            Method::Lora { rank: if d >= 4096 { 64 } else { 32 } },
            Method::Circulant { backend: Backend::Fft, p },
            Method::Circulant { backend: Backend::Rfft, p },
            Method::Circulant { backend: Backend::RdFft, p },
        ];
        for m in methods {
            let cell = measure_single_layer_with_state(m, d, b, 1);
            let s = cell.snapshot;
            let mut row = format!("{:<16}", m.label());
            for cat in CATEGORIES {
                row.push_str(&format!("{:>12}", fmt_mib(s.at_peak[cat.index()])));
            }
            println!("{row}{:>12}", fmt_mib(s.peak_total));
        }
    }
    println!(
        "\n(note: 'intermediate' at the peak is the paper's forward-pass\n\
         transient-tensor bar; rdFFT rows must show ~0 there)"
    );
}

/// Table 2: analytical full-model memory decomposition for LLaMA2-7B and
/// RoBERTa-large (see `crate::model` for the formulas and DESIGN.md §2
/// for why analytical substitution is sound here).
pub fn table2() {
    use crate::model::{table2_row, ArchSpec};
    for arch in [ArchSpec::llama2_7b(), ArchSpec::roberta_large()] {
        println!("\n# Table 2 — {} (analytical, paper decomposition)", arch.name);
        println!(
            "{:<16}{:>12}{:>15}{:>15}{:>12}{:>12}",
            "method", "model(GB)", "trainable(MB)", "gradient(MB)", "others(GB)", "total(GB)"
        );
        let gib = 1024.0 * 1024.0 * 1024.0;
        let mib = 1024.0 * 1024.0;
        let (loras, ps): (Vec<usize>, Vec<usize>) = if arch.name.starts_with("LLaMA") {
            (vec![32, 64], vec![512, 1024, 4096])
        } else {
            (vec![8, 16], vec![256, 512, 1024])
        };
        let mut methods = vec![Method::FullFinetune];
        methods.extend(loras.iter().map(|&r| Method::Lora { rank: r }));
        for &p in &ps {
            for bk in BACKENDS {
                methods.push(Method::Circulant { backend: bk, p });
            }
        }
        for m in methods {
            let row = table2_row(&arch, m);
            println!(
                "{:<16}{:>12.2}{:>15.2}{:>15.2}{:>12.2}{:>12.2}",
                row.method,
                row.model_bytes as f64 / gib,
                row.trainable_bytes as f64 / mib,
                row.gradient_bytes as f64 / mib,
                row.others_bytes as f64 / gib,
                row.total_bytes() as f64 / gib,
            );
        }
    }
}

/// Table 3: standalone operator runtime (median, µs) and numerical
/// accuracy vs the f64 naive-DFT oracle, p ∈ {512, 1024, 4096}.
pub fn table3() {
    println!("# Table 3 — operator runtime (µs, median) and accuracy vs f64 DFT\n");
    println!(
        "{:<8}{:>6}{:>14}{:>14}{:>14}{:>16}{:>14}",
        "p", "op", "fft", "rfft", "ours", "abs.err(ours)", "rel.err(ours)"
    );
    for &n in &[512usize, 1024, 4096] {
        let plan = cached(n);
        let x: Vec<f32> = (0..n).map(|i| ((i * 37 + 11) % 97) as f32 / 48.0 - 1.0).collect();

        // -------- runtimes
        let fft_fwd = bench(300, || {
            let s = complex_fft::fft_out_of_place(&x, Category::Other);
            std::hint::black_box(&s[0]);
        });
        let spec_c = complex_fft::fft_out_of_place(&x, Category::Other);
        let fft_inv = bench(300, || {
            let s = complex_fft::ifft_out_of_place(&spec_c, Category::Other);
            std::hint::black_box(&s[0]);
        });
        let rfft_fwd = bench(300, || {
            let s = rfft::rfft_alloc(&x, Category::Other);
            std::hint::black_box(&s[0]);
        });
        let spec_r = rfft::rfft_alloc(&x, Category::Other);
        let rfft_inv = bench(300, || {
            let s = rfft::irfft_alloc(&spec_r, Category::Other);
            std::hint::black_box(&s[0]);
        });
        let mut buf = x.clone();
        let ours_fwd = bench(300, || {
            rdfft::rdfft_inplace(&plan, &mut buf);
            std::hint::black_box(&buf[0]);
        });
        let ours_inv = bench(300, || {
            rdfft::irdfft_inplace(&plan, &mut buf);
            std::hint::black_box(&buf[0]);
        });

        // -------- accuracy vs f64 oracle
        let oracle = baselines::naive_dft(&x);
        let mut packed = x.clone();
        rdfft::rdfft_inplace(&plan, &mut packed);
        let (mut abs, mut rel_num, mut rel_den) = (0f64, 0f64, 0f64);
        for k in 0..=n / 2 {
            let got = crate::rdfft::layout::get(&packed, k);
            let want = oracle[k];
            let e = (((got.0 - want.0) as f64).powi(2) + ((got.1 - want.1) as f64).powi(2)).sqrt();
            abs = abs.max(e);
            rel_num += e * e;
            rel_den += (want.0 as f64).powi(2) + (want.1 as f64).powi(2);
        }
        let rel = (rel_num / rel_den.max(1e-30)).sqrt();

        println!(
            "{:<8}{:>6}{:>14.2}{:>14.2}{:>14.2}{:>16.3e}{:>14.3e}",
            n, "fwd", fft_fwd.median_us(), rfft_fwd.median_us(), ours_fwd.median_us(), abs, rel
        );
        println!(
            "{:<8}{:>6}{:>14.2}{:>14.2}{:>14.2}{:>16}{:>14}",
            n, "inv", fft_inv.median_us(), rfft_inv.median_us(), ours_inv.median_us(), "-", "-"
        );
    }
    println!(
        "\n(paper shape to check: ours ≈ rfft at small p, overhead at 4096;\n\
         ours-inverse faster than ours-forward; errors at float-noise level)"
    );
}

/// Table 4: training throughput (tokens/s on an adapted layer at
/// LLaMA-like width) and task accuracy parity on the synthetic MRPC-like
/// classification task.
pub fn table4(fast: bool) {
    let d = if fast { 512 } else { 1024 };
    let (steps, n_train) = if fast { (30, 256) } else { (60, 512) };
    println!("# Table 4 — throughput (k tokens/s) and task accuracy (%)\n");
    println!("{:<16}{:>14}{:>12}{:>12}", "method", "thr(ktok/s)", "acc(%)", "loss");
    let task = ClassifyTask::synthesize(d, n_train, n_train / 2, 5);
    let mut methods =
        vec![Method::FullFinetune, Method::Lora { rank: 32 }];
    for &p in if fast { &[128usize, 256][..] } else { &[128usize, 512, 1024][..] } {
        for bk in BACKENDS {
            methods.push(Method::Circulant { backend: bk, p });
        }
    }
    for m in methods {
        let r = finetune_classifier(&task, m, steps, 16, 0.2, 11);
        println!(
            "{:<16}{:>14.2}{:>12.1}{:>12.4}",
            r.method,
            r.tokens_per_sec / 1e3,
            r.test_accuracy * 100.0,
            r.final_train_loss
        );
    }
    println!(
        "\n(paper shape: FF/LoRA fastest; ours slower than rfft but with the\n\
         memory advantage of Table 1; all circulant accuracies within noise)"
    );
}

/// Supplementary: verify the zero-allocation claim directly (the number
/// the whole paper rests on).
pub fn alloc_audit() {
    println!("# Allocation audit — tensor allocations during one fwd+bwd step\n");
    println!("{:<16}{:>14}{:>18}", "method", "allocs", "transient bytes");
    for bk in BACKENDS {
        let m = Method::Circulant { backend: bk, p: 256 };
        crate::memtrack::reset();
        let mut layer = m.build(1024, 1);
        crate::memtrack::reset_peak();
        let x = crate::autograd::Tensor::rand(
            4,
            1024,
            1.0,
            2,
            Category::Intermediates,
        );
        let y = layer.forward(x);
        let mut g = crate::autograd::Tensor::zeros_cat(4, 1024, Category::Intermediates);
        g.fill(1.0);
        drop(y);
        let _dx = layer.backward(g);
        let s = crate::memtrack::snapshot();
        println!(
            "{:<16}{:>14}{:>18}",
            m.label(),
            s.alloc_count,
            s.peak_by_cat[Category::Intermediates.index()]
        );
    }
}

/// Ablation: optimizer-state memory per method at LLaMA2-7B scale — why
/// the paper trains with plain SGD (§5.1.2 "We use stochastic gradient
/// descent (SGD) as the optimizer in all experiments"). Adam on full
/// fine-tuning alone would dwarf every operator-level saving.
pub fn optim_ablation() {
    use crate::autograd::optim::OptimKind;
    use crate::model::ArchSpec;
    let arch = ArchSpec::llama2_7b();
    let kinds = [
        OptimKind::Sgd,
        OptimKind::Momentum { beta: 0.9 },
        OptimKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
    ];
    println!("# Optimizer-state memory at {} scale (GB, fp32 state)\n", arch.name);
    println!("{:<16}{:>10}{:>12}{:>10}", "method", "sgd", "momentum", "adam");
    let gib = 1024.0f64 * 1024.0 * 1024.0;
    for m in [
        Method::FullFinetune,
        Method::Lora { rank: 32 },
        Method::Circulant { backend: Backend::RdFft, p: 512 },
        Method::Circulant { backend: Backend::RdFft, p: 4096 },
    ] {
        let params = arch.trainable_params(m);
        let mut row = format!("{:<16}", m.label());
        for k in kinds {
            row.push_str(&format!(
                "{:>10.3}",
                (params * k.state_per_param() * 4) as f64 / gib
            ));
        }
        println!("{row}");
    }
    println!(
        "\n(read: adapter methods make even Adam affordable — 2×8 MB —\n\
         while full fine-tuning pays 50 GB; the paper's SGD choice only\n\
         matters for the FF baseline, so comparisons stay fair)"
    );
}

/// Batch-engine ablation: scalar per-row loop vs batch-major engine vs
/// batch-major + threads, over (n × batch), the circulant
/// fused-vs-unfused pipeline comparison, and the persistent-pool vs
/// per-call scoped-thread scaling grid (threads ∈ {1, 2, 4} at
/// n = 4096, batch = 32 — the `*_pool` acceptance rows, with the
/// ≥ 1.15× pool-vs-scoped gate emitted into the JSON). Each timed
/// closure is one forward+inverse roundtrip of the whole batch (keeps
/// values bounded across iterations), plus the width-8-vs-width-4 lane
/// cell and the wall-clock-budgeted four-step-vs-direct large-n cells.
/// Prints the grids and writes the machine-readable records + gates to
/// `BENCH_rdfft.json` (schema v3 in EXPERIMENTS.md §Perf).
///
/// Returns `false` when a hard gate failed — the single-row latency
/// gate (engine batch=1 slower than the scalar path beyond measurement
/// slack), the fused-circulant gate (fused sweep slower than the
/// unfused three-pass pipeline on a ≥ 8 Ki-element cell), or the pool
/// outright regressing below the scoped path at threads = 4 — so bench
/// binaries can exit non-zero instead of burying a `REGRESSED` cell in
/// the log. The 1.15× pool target itself is reported in the `gates`
/// array (pass/fail), not hard-gated: shared CI boxes are too noisy.
pub fn bench_rdfft_engine(fast: bool) -> bool {
    use crate::coordinator::benchlib::{write_bench_json, BenchGate, BenchRecord};
    use crate::runtime::pool::ExecCtx;
    use crate::rdfft::engine::{self, EngineConfig, SpectralOp};
    use crate::rdfft::forward::rdfft_batch_scalar;
    use crate::rdfft::inverse::irdfft_batch_scalar;
    use crate::rdfft::spectral;

    let budget = if fast { 60 } else { 200 };
    let ns = [256usize, 1024, 4096];
    let batches: &[usize] = if fast { &[1, 8] } else { &[1, 8, 32] };
    let serial = EngineConfig::serial();
    // Pre-build the grid's plans as parallel pool jobs so no timed cell
    // pays first-use plan construction.
    crate::rdfft::plan::warm_cache(&ns, &ExecCtx::global());

    println!("# rdFFT batch engine — fwd+inv roundtrip, median ns per row-transform\n");
    println!(
        "{:<8}{:>8}{:>14}{:>14}{:>14}{:>10}{:>10}{:>12}",
        "n", "batch", "scalar", "batch-major", "bm+threads", "bm×", "thr×", "b1-gate"
    );
    let mut records = Vec::new();
    let mut gates_ok = true;
    for &n in &ns {
        let plan = cached(n);
        for &b in batches {
            let mut buf: Vec<f32> =
                (0..n * b).map(|i| ((i * 31 + 17) % 101) as f32 / 50.0 - 1.0).collect();
            let s_scalar = bench(budget, || {
                rdfft_batch_scalar(&plan, &mut buf);
                irdfft_batch_scalar(&plan, &mut buf);
                std::hint::black_box(&buf[0]);
            });
            let s_bm = bench(budget, || {
                engine::forward_batch_with(&plan, &mut buf, &serial);
                engine::inverse_batch_with(&plan, &mut buf, &serial);
                std::hint::black_box(&buf[0]);
            });
            let s_thr = bench(budget, || {
                engine::forward_batch(&plan, &mut buf);
                engine::inverse_batch(&plan, &mut buf);
                std::hint::black_box(&buf[0]);
            });
            // per row-transform: each closure iteration performs 2*b
            // transforms (b forward + b inverse)
            let per = |s: &crate::coordinator::benchlib::Stats| s.median_ns / (2.0 * b as f64);
            let tps = |s: &crate::coordinator::benchlib::Stats| {
                2.0 * b as f64 / (s.median_ns.max(1.0) / 1e9)
            };
            let bm_x = s_scalar.median_ns / s_bm.median_ns.max(1.0);
            let thr_x = s_scalar.median_ns / s_thr.median_ns.max(1.0);
            // Single-row latency gate: the engine's batch=1 path must not
            // regress vs the seed scalar transform (10% measurement slack
            // — shared CI machines are noisy).
            let gate = if b == 1 {
                if s_thr.median_ns <= s_scalar.median_ns * 1.10 {
                    "ok"
                } else {
                    gates_ok = false;
                    "REGRESSED"
                }
            } else {
                "-"
            };
            println!(
                "{:<8}{:>8}{:>14.0}{:>14.0}{:>14.0}{:>10.2}{:>10.2}{:>12}",
                n,
                b,
                per(&s_scalar),
                per(&s_bm),
                per(&s_thr),
                bm_x,
                thr_x,
                gate
            );
            for (mode, stats, speedup) in [
                ("scalar", s_scalar, 1.0),
                ("batch_major", s_bm, bm_x),
                ("batch_threads", s_thr, thr_x),
            ] {
                records.push(BenchRecord {
                    mode: mode.to_string(),
                    n,
                    batch: b,
                    threads: 0,
                    transforms_per_sec: tps(&stats),
                    stats,
                    speedup_vs_scalar: speedup,
                });
            }

            // Circulant apply, fused single-sweep pipeline vs the unfused
            // forward → packed product → inverse three-pass pipeline at
            // the same (n, batch). The δ spectrum (the ⊙ identity) keeps
            // repeated applications numerically bounded across timing
            // iterations. For the fused record, `speedup_vs_scalar`
            // reports fused-vs-unfused (the tentpole's acceptance ratio).
            let mut spec = vec![0.0f32; n];
            spec[0] = 1.0;
            rdfft::rdfft_inplace(&plan, &mut spec);
            let s_unf = bench(budget, || {
                engine::forward_batch(&plan, &mut buf);
                for row in buf.chunks_exact_mut(n) {
                    spectral::mul_inplace(row, &spec);
                }
                engine::inverse_batch(&plan, &mut buf);
                std::hint::black_box(&buf[0]);
            });
            let s_fus = bench(budget, || {
                engine::circulant_apply_batch(&plan, &mut buf, &spec, SpectralOp::Mul);
                std::hint::black_box(&buf[0]);
            });
            let fus_x = s_unf.median_ns / s_fus.median_ns.max(1.0);
            // Regression gate: on cells with enough work to time stably
            // (≥ 8 Ki elements), the fused sweep must not be slower than
            // the unfused pipeline beyond measurement slack. (The ≥ 1.2×
            // acceptance target is judged on the large cells and
            // reported, not hard-gated — tiny L1-resident cells have
            // little bandwidth to win back.)
            let fus_gate = if n * b >= 1 << 13 {
                if fus_x >= 0.9 {
                    "ok"
                } else {
                    gates_ok = false;
                    "REGRESSED"
                }
            } else {
                "-"
            };
            println!(
                "{:<8}{:>8}  circulant-apply: unfused {:>10.0}  fused {:>10.0}  fused× {:>5.2}  {}",
                n,
                b,
                s_unf.median_ns / b as f64,
                s_fus.median_ns / b as f64,
                fus_x,
                fus_gate
            );
            for (mode, stats, speedup) in
                [("circulant_unfused", s_unf, 1.0), ("circulant_fused", s_fus, fus_x)]
            {
                records.push(BenchRecord {
                    mode: mode.to_string(),
                    n,
                    batch: b,
                    threads: 0,
                    transforms_per_sec: tps(&stats),
                    stats,
                    speedup_vs_scalar: speedup,
                });
            }
        }
    }
    // ------------------------------------------------------------------
    // Persistent pool vs per-call scoped threads — the thread-scaling
    // grid at the tentpole's acceptance cell (n = 4096, batch = 32),
    // threads ∈ {1, 2, 4}. Scoped rows pay a fresh std::thread::scope
    // spawn per call (the pre-pool behaviour, kept as the oracle); pool
    // rows dispatch the same chunks as jobs on parked workers.
    // `speedup_vs_scalar` on `*_pool` rows carries pool-vs-scoped at
    // equal thread count — the ≥ 1.15× acceptance ratio at threads = 4.
    // ------------------------------------------------------------------
    let mut gates: Vec<BenchGate> = Vec::new();
    {
        let (pn, pb) = (4096usize, 32usize);
        let pplan = cached(pn);
        let mut pbuf: Vec<f32> =
            (0..pn * pb).map(|i| ((i * 29 + 13) % 97) as f32 / 48.0 - 1.0).collect();
        let mut pspec = vec![0.0f32; pn];
        pspec[0] = 1.0;
        rdfft::rdfft_inplace(&pplan, &mut pspec);
        println!(
            "\n# persistent pool vs per-call scoped threads — n={pn}, batch={pb}, \
             fwd+inv roundtrip (batch) and fused circulant apply, ns/row"
        );
        println!(
            "{:<8}{:>14}{:>12}{:>8}{:>14}{:>12}{:>8}",
            "threads", "scoped", "pool", "pool×", "f-scoped", "f-pool", "pool×"
        );
        for &t in &[1usize, 2, 4] {
            let cfg_t = EngineConfig { max_threads: t, ..EngineConfig::new() };
            let ctx_t = ExecCtx::with_threads(t);
            let s_scoped = bench(budget, || {
                engine::forward_batch_scoped(&pplan, &mut pbuf, &cfg_t);
                engine::inverse_batch_scoped(&pplan, &mut pbuf, &cfg_t);
                std::hint::black_box(&pbuf[0]);
            });
            let s_pool = bench(budget, || {
                engine::forward_batch_ctx(&pplan, &mut pbuf, &ctx_t);
                engine::inverse_batch_ctx(&pplan, &mut pbuf, &ctx_t);
                std::hint::black_box(&pbuf[0]);
            });
            let f_scoped = bench(budget, || {
                engine::circulant_apply_batch_scoped(
                    &pplan, &mut pbuf, &pspec, SpectralOp::Mul, &cfg_t,
                );
                std::hint::black_box(&pbuf[0]);
            });
            let f_pool = bench(budget, || {
                engine::circulant_apply_batch_ctx(
                    &pplan, &mut pbuf, &pspec, SpectralOp::Mul, &ctx_t,
                );
                std::hint::black_box(&pbuf[0]);
            });
            let bx = s_scoped.median_ns / s_pool.median_ns.max(1.0);
            let fx = f_scoped.median_ns / f_pool.median_ns.max(1.0);
            println!(
                "{:<8}{:>14.0}{:>12.0}{:>8.2}{:>14.0}{:>12.0}{:>8.2}",
                t,
                s_scoped.median_ns / (2.0 * pb as f64),
                s_pool.median_ns / (2.0 * pb as f64),
                bx,
                f_scoped.median_ns / pb as f64,
                f_pool.median_ns / pb as f64,
                fx
            );
            let ptps = |s: &crate::coordinator::benchlib::Stats| {
                2.0 * pb as f64 / (s.median_ns.max(1.0) / 1e9)
            };
            for (mode, stats, speedup) in [
                ("batch_scoped", s_scoped, 1.0),
                ("batch_pool", s_pool, bx),
                ("circulant_fused_scoped", f_scoped, 1.0),
                ("circulant_fused_pool", f_pool, fx),
            ] {
                records.push(BenchRecord {
                    mode: mode.to_string(),
                    n: pn,
                    batch: pb,
                    threads: t,
                    transforms_per_sec: ptps(&stats),
                    stats,
                    speedup_vs_scalar: speedup,
                });
            }
            if t == 4 {
                // The acceptance gate (emitted into BENCH_rdfft.json):
                // pool ≥ 1.15× the per-call scoped path at threads = 4.
                // `pass` records the target honestly; only a clear
                // regression (< 0.85×, i.e. beyond the same noise band
                // that keeps 1.15× advisory) hard-fails the bench —
                // shared CI boxes routinely wobble a true ~1.1× ratio
                // a few percent either side of 1.0.
                for (name, ratio) in [
                    ("pool_vs_scoped_batch", bx),
                    ("pool_vs_scoped_circulant_fused", fx),
                ] {
                    if ratio < 0.85 {
                        gates_ok = false;
                    }
                    gates.push(BenchGate {
                        name: name.to_string(),
                        threads: t,
                        n: pn,
                        batch: pb,
                        ratio,
                        target: 1.15,
                        pass: ratio >= 1.15,
                    });
                }
            }
        }
        for g in &gates {
            println!(
                "gate {}: ratio {:.2} (target {:.2}) -> {}",
                g.name,
                g.ratio,
                g.target,
                if g.pass { "pass" } else { "MISS" }
            );
        }
    }

    // ------------------------------------------------------------------
    // SIMD lane kernels vs the forced-scalar oracle — the PR-6 acceptance
    // cell (n = 4096, batch = 32), measured serially so the ratio
    // isolates the lane kernels from thread scaling. Emitted as the
    // `batch_simd` / `circulant_fused_simd` rows (speedup_vs_scalar =
    // auto-arm vs forced-scalar at equal config) plus the
    // `simd_vs_scalar` gates (target ≥ 1.5 on AVX2+FMA hardware). On
    // machines without FMA lanes the auto arm is the bit-identical
    // portable quad arm and the ratio sits near 1.0 — the gate records
    // that honestly (pass=false) without hard-failing; a hard failure
    // needs the FMA arm to actually *regress* below 0.9× scalar.
    // ------------------------------------------------------------------
    {
        use crate::rdfft::simd;
        let (sn, sb) = (4096usize, 32usize);
        let splan = cached(sn);
        let mut sbuf: Vec<f32> =
            (0..sn * sb).map(|i| ((i * 37 + 11) % 89) as f32 / 44.0 - 1.0).collect();
        let mut sspec = vec![0.0f32; sn];
        sspec[0] = 1.0;
        rdfft::rdfft_inplace(&splan, &mut sspec);
        let scalar_cfg = EngineConfig::forced_scalar_serial();
        let simd_cfg = EngineConfig::serial();
        let arm = simd::active();
        println!(
            "\n# SIMD lane kernels vs forced-scalar oracle — n={sn}, batch={sb}, serial, \
             active arm: {arm:?}"
        );
        let s_scal = bench(budget, || {
            engine::forward_batch_with(&splan, &mut sbuf, &scalar_cfg);
            engine::inverse_batch_with(&splan, &mut sbuf, &scalar_cfg);
            std::hint::black_box(&sbuf[0]);
        });
        let s_simd = bench(budget, || {
            engine::forward_batch_with(&splan, &mut sbuf, &simd_cfg);
            engine::inverse_batch_with(&splan, &mut sbuf, &simd_cfg);
            std::hint::black_box(&sbuf[0]);
        });
        let f_scal = bench(budget, || {
            engine::circulant_apply_batch_with(&splan, &mut sbuf, &sspec, SpectralOp::Mul, &scalar_cfg);
            std::hint::black_box(&sbuf[0]);
        });
        let f_simd = bench(budget, || {
            engine::circulant_apply_batch_with(&splan, &mut sbuf, &sspec, SpectralOp::Mul, &simd_cfg);
            std::hint::black_box(&sbuf[0]);
        });
        let sx = s_scal.median_ns / s_simd.median_ns.max(1.0);
        let fx = f_scal.median_ns / f_simd.median_ns.max(1.0);
        println!(
            "{:<24}{:>14}{:>14}{:>8}",
            "mode", "scalar ns/row", "simd ns/row", "simd×"
        );
        println!(
            "{:<24}{:>14.0}{:>14.0}{:>8.2}",
            "batch fwd+inv",
            s_scal.median_ns / (2.0 * sb as f64),
            s_simd.median_ns / (2.0 * sb as f64),
            sx
        );
        println!(
            "{:<24}{:>14.0}{:>14.0}{:>8.2}",
            "circulant fused",
            f_scal.median_ns / sb as f64,
            f_simd.median_ns / sb as f64,
            fx
        );
        let stps = |s: &crate::coordinator::benchlib::Stats, per: f64| {
            per * sb as f64 / (s.median_ns.max(1.0) / 1e9)
        };
        for (mode, stats, speedup, per) in [
            ("batch_forced_scalar", s_scal, 1.0, 2.0),
            ("batch_simd", s_simd, sx, 2.0),
            ("circulant_fused_forced_scalar", f_scal, 1.0, 1.0),
            ("circulant_fused_simd", f_simd, fx, 1.0),
        ] {
            records.push(BenchRecord {
                mode: mode.to_string(),
                n: sn,
                batch: sb,
                threads: 0,
                transforms_per_sec: stps(&stats, per),
                stats,
                speedup_vs_scalar: speedup,
            });
        }
        let fma_active = arm.uses_fma();
        for (name, ratio) in [("simd_vs_scalar", sx), ("simd_vs_scalar_circulant_fused", fx)] {
            // A clear regression of the active FMA arm hard-fails; the
            // 1.5× target itself is recorded, not hard-gated (portable
            // arms and noisy shared boxes legitimately miss it).
            if fma_active && ratio < 0.9 {
                gates_ok = false;
            }
            gates.push(BenchGate {
                name: name.to_string(),
                threads: 0,
                n: sn,
                batch: sb,
                ratio,
                target: 1.5,
                pass: ratio >= 1.5,
            });
            println!(
                "gate {name}: ratio {ratio:.2} (target 1.50) -> {}",
                if ratio >= 1.5 { "pass" } else { "MISS" }
            );
        }
    }

    // ------------------------------------------------------------------
    // Width-8 lanes vs the width-4 quad arm — same serial acceptance
    // cell as the SIMD section, with `max_simd_width = 4` pinning the
    // baseline to the 128-bit quad kernels. On hardware where the
    // 256-bit arm is not selected the two configs run identical code and
    // the ratio sits near 1.0 — recorded honestly (pass=false), never
    // hard-failed; a hard failure needs the active AvxFma256 arm to
    // *regress* below 0.9× its own quad arm.
    // ------------------------------------------------------------------
    {
        use crate::rdfft::simd;
        let (sn, sb) = (4096usize, 32usize);
        let splan = cached(sn);
        let mut sbuf: Vec<f32> =
            (0..sn * sb).map(|i| ((i * 41 + 7) % 83) as f32 / 41.0 - 1.0).collect();
        let w4_cfg = EngineConfig { max_simd_width: 4, ..EngineConfig::serial() };
        let w8_cfg = EngineConfig::serial();
        let s4 = bench(budget, || {
            engine::forward_batch_with(&splan, &mut sbuf, &w4_cfg);
            engine::inverse_batch_with(&splan, &mut sbuf, &w4_cfg);
            std::hint::black_box(&sbuf[0]);
        });
        let s8 = bench(budget, || {
            engine::forward_batch_with(&splan, &mut sbuf, &w8_cfg);
            engine::inverse_batch_with(&splan, &mut sbuf, &w8_cfg);
            std::hint::black_box(&sbuf[0]);
        });
        let wx = s4.median_ns / s8.median_ns.max(1.0);
        let oct_active = matches!(simd::active(), simd::Kernels::AvxFma256);
        println!(
            "\n# width-8 lanes vs width-4 quad arm — n={sn}, batch={sb}, serial, \
             256-bit arm active: {oct_active}"
        );
        println!(
            "width-4 {:>10.0} ns/row   width-8 {:>10.0} ns/row   w8× {:>5.2}",
            s4.median_ns / (2.0 * sb as f64),
            s8.median_ns / (2.0 * sb as f64),
            wx
        );
        let wtps = |s: &crate::coordinator::benchlib::Stats| {
            2.0 * sb as f64 / (s.median_ns.max(1.0) / 1e9)
        };
        for (mode, stats, speedup) in [("batch_simd4", s4, 1.0), ("batch_simd8", s8, wx)] {
            records.push(BenchRecord {
                mode: mode.to_string(),
                n: sn,
                batch: sb,
                threads: 0,
                transforms_per_sec: wtps(&stats),
                stats,
                speedup_vs_scalar: speedup,
            });
        }
        if oct_active && wx < 0.9 {
            gates_ok = false;
        }
        gates.push(BenchGate {
            name: "simd8_vs_simd4".to_string(),
            threads: 0,
            n: sn,
            batch: sb,
            ratio: wx,
            target: 1.25,
            pass: wx >= 1.25,
        });
        println!(
            "gate simd8_vs_simd4: ratio {wx:.2} (target 1.25) -> {}",
            if wx >= 1.25 { "pass" } else { "MISS" }
        );
    }

    // ------------------------------------------------------------------
    // Long-convolution layer — the fused single-sweep pipeline with
    // persistent workspaces (the serve/steady-state path) vs the unfused
    // three-pass oracle (forward batch → packed product → inverse batch
    // → separate GELU/skip pass, fresh buffers per call). The numerics
    // of the two pipelines are pinned tile-for-tile by the layer's
    // differential test; this cell pins the performance claim and the
    // `longconv_fused_vs_unfused` gate records it in BENCH_rdfft.json.
    // ------------------------------------------------------------------
    {
        use crate::autograd::layers::Layer;
        use crate::autograd::{LongConvLayer, Tensor};
        let (ld, lk, lb) = (1024usize, 257usize, 32usize);
        let mut layer = LongConvLayer::new(ld, lk, 5);
        let ln = layer.fft_size();
        let mut x = Tensor::rand(lb, ld, 1.0, 6, Category::Other);
        let mut out = Tensor::zeros_cat(lb, ld, Category::Other);
        // Materialize the kernel spectrum once — both legs then amortize
        // one FFT of h over every row they touch (the Mathieu et al.
        // argument the layer is built on).
        layer.begin_shard_step();
        let s_unf = bench(budget, || {
            let y = layer.forward_residual_unfused(&x);
            std::hint::black_box(y.as_slice()[0]);
        });
        let s_fus = bench(budget, || {
            layer.infer_forward_residual(&mut x, &mut out);
            std::hint::black_box(out.as_slice()[0]);
        });
        let lx = s_unf.median_ns / s_fus.median_ns.max(1.0);
        println!(
            "\n# long-conv layer — fused single-sweep vs unfused three-pass, \
             d={ld} k={lk} (fft n={ln}) batch={lb}, ns/row"
        );
        println!(
            "unfused {:>10.0} ns/row   fused {:>10.0} ns/row   fused× {:>5.2}",
            s_unf.median_ns / lb as f64,
            s_fus.median_ns / lb as f64,
            lx
        );
        let ltps = |s: &crate::coordinator::benchlib::Stats| {
            lb as f64 / (s.median_ns.max(1.0) / 1e9)
        };
        for (mode, stats, speedup) in
            [("longconv_unfused", s_unf, 1.0), ("longconv_fused", s_fus, lx)]
        {
            records.push(BenchRecord {
                mode: mode.to_string(),
                n: ln,
                batch: lb,
                threads: 0,
                transforms_per_sec: ltps(&stats),
                stats,
                speedup_vs_scalar: speedup,
            });
        }
        // Same shape as the circulant fused gate: the 1.2× target is
        // recorded; only a clear regression below the unfused pipeline
        // hard-fails (the fused sweep also skips two whole-buffer
        // walks, so < 0.9× means the fusion itself broke).
        if lx < 0.9 {
            gates_ok = false;
        }
        gates.push(BenchGate {
            name: "longconv_fused_vs_unfused".to_string(),
            threads: 0,
            n: ln,
            batch: lb,
            ratio: lx,
            target: 1.2,
            pass: lx >= 1.2,
        });
        println!(
            "gate longconv_fused_vs_unfused: ratio {lx:.2} (target 1.20) -> {}",
            if lx >= 1.2 { "pass" } else { "MISS" }
        );
    }

    // ------------------------------------------------------------------
    // Four-step (Bailey) large-n tier vs the direct stage sweep —
    // wall-clock-budgeted cells (one call per sample, no batch
    // calibration: a single 262 Ki roundtrip is already milliseconds).
    // `fourstep_threshold: usize::MAX` pins the baseline to the direct
    // sweep; default tuning takes the tier at every cell. The gate is
    // emitted at the largest measured n; it only hard-fails when the
    // full-size 262 Ki cell was measured and the tier is a clear
    // regression (< 0.9×) there — the ≥ 1.3× target is advisory
    // (bandwidth wins depend on the box's cache/DRAM ratio).
    // ------------------------------------------------------------------
    {
        use crate::coordinator::benchlib::bench_budgeted;
        let cells: &[(usize, usize)] = if fast {
            &[(1 << 14, 4), (1 << 16, 2)]
        } else {
            &[(1 << 14, 8), (1 << 16, 4), (1 << 18, 2)]
        };
        let direct_cfg = EngineConfig { fourstep_threshold: usize::MAX, ..EngineConfig::new() };
        let four_cfg = EngineConfig::new();
        println!(
            "\n# four-step (Bailey) large-n tier vs direct stage sweep — fwd+inv \
             roundtrip, budgeted single-call samples, ns/row"
        );
        println!(
            "{:<10}{:>8}{:>16}{:>16}{:>8}{:>14}",
            "n", "batch", "direct", "fourstep", "4s×", "tier"
        );
        let mut last_cell: Option<(usize, usize, f64)> = None;
        for &(n, b) in cells {
            let plan = cached(n);
            let mut buf: Vec<f32> =
                (0..n * b).map(|i| ((i * 43 + 19) % 103) as f32 / 51.0 - 1.0).collect();
            // Tier telemetry brackets each timed leg: a "fourstep" cell
            // that silently ran the direct sweep (threshold met but the
            // plan had no tables — the old silent-fallback bug) would
            // make the ratio a lie, so a mismeasured cell hard-fails
            // instead of being written into BENCH_rdfft.json as real.
            let t0 = engine::tier_counts();
            let s_direct = bench_budgeted(budget, || {
                engine::forward_batch_with(&plan, &mut buf, &direct_cfg);
                engine::inverse_batch_with(&plan, &mut buf, &direct_cfg);
                std::hint::black_box(&buf[0]);
            });
            let t1 = engine::tier_counts();
            let s_four = bench_budgeted(budget, || {
                engine::forward_batch_with(&plan, &mut buf, &four_cfg);
                engine::inverse_batch_with(&plan, &mut buf, &four_cfg);
                std::hint::black_box(&buf[0]);
            });
            let t2 = engine::tier_counts();
            let d_leg = t1.since(t0);
            let f_leg = t2.since(t1);
            let tier_ok = d_leg.fourstep == 0
                && d_leg.fallback == 0
                && f_leg.fourstep > 0
                && f_leg.fallback == 0;
            let tier_label = if tier_ok {
                "engaged".to_string()
            } else {
                gates_ok = false;
                format!("MISMEASURED(4s={},fb={})", f_leg.fourstep, f_leg.fallback)
            };
            gates.push(BenchGate {
                name: "fourstep_tier_engaged".to_string(),
                threads: 0,
                n,
                batch: b,
                // engaged fraction of the four-step leg's transforms
                ratio: f_leg.fourstep as f64
                    / (f_leg.fourstep + f_leg.direct + f_leg.fallback).max(1) as f64,
                target: 1.0,
                pass: tier_ok,
            });
            let fx = s_direct.median_ns / s_four.median_ns.max(1.0);
            println!(
                "{:<10}{:>8}{:>16.0}{:>16.0}{:>8.2}{:>14}",
                n,
                b,
                s_direct.median_ns / (2.0 * b as f64),
                s_four.median_ns / (2.0 * b as f64),
                fx,
                tier_label
            );
            let ltps = |s: &crate::coordinator::benchlib::Stats| {
                2.0 * b as f64 / (s.median_ns.max(1.0) / 1e9)
            };
            for (mode, stats, speedup) in
                [("batch_direct", s_direct, 1.0), ("batch_fourstep", s_four, fx)]
            {
                records.push(BenchRecord {
                    mode: mode.to_string(),
                    n,
                    batch: b,
                    threads: 0,
                    transforms_per_sec: ltps(&stats),
                    stats,
                    speedup_vs_scalar: speedup,
                });
            }
            last_cell = Some((n, b, fx));
        }
        if let Some((n, b, ratio)) = last_cell {
            if n == 1 << 18 && ratio < 0.9 {
                gates_ok = false;
            }
            gates.push(BenchGate {
                name: "fourstep_vs_direct".to_string(),
                threads: 0,
                n,
                batch: b,
                ratio,
                target: 1.3,
                pass: ratio >= 1.3,
            });
            println!(
                "gate fourstep_vs_direct: ratio {ratio:.2} at n={n} (target 1.30) -> {}",
                if ratio >= 1.3 { "pass" } else { "MISS" }
            );
        }
    }

    println!(
        "\n(gates: batch-major+threads >= 2x scalar at batch >= 8 where the\n\
         work threshold engages; batch=1 must ride the spawn-free path and\n\
         stay at or below scalar latency; circulant fused× target >= 1.2\n\
         on the grid; pool >= 1.15x per-call scoped threads at threads=4;\n\
         SIMD lane kernels >= 1.5x the forced-scalar oracle at n=4096\n\
         b=32 on AVX2+FMA hardware; width-8 >= 1.25x width-4 when the\n\
         256-bit arm is active; long-conv fused sweep >= 1.2x the unfused\n\
         three-pass pipeline (advisory; < 0.9x hard-fails); four-step\n\
         >= 1.3x direct at n=262144 (advisory; < 0.9x there hard-fails,\n\
         and any fourstep cell that silently ran the direct sweep\n\
         hard-fails as mismeasured) — see EXPERIMENTS.md §Perf)"
    );
    let path = std::path::Path::new("BENCH_rdfft.json");
    match write_bench_json(path, &records, &gates) {
        Ok(()) => println!(
            "wrote {} ({} records, {} gates)",
            path.display(),
            records.len(),
            gates.len()
        ),
        Err(e) => eprintln!("failed to write {}: {e}", path.display()),
    }
    gates_ok
}

/// Cheap four-step correctness smoke for CI (`repro engine
/// --fourstep-smoke`): no timing, just the large-n tier vs the direct
/// sweep at n = 16 Ki on whatever dispatch arm the process resolved
/// (CI runs it twice — plain and `RDFFT_FORCE_SCALAR=1`). Returns
/// `false` (so the binary exits non-zero) when the tier disagrees with
/// the direct path beyond the n-scaled tolerance or the roundtrip drifts.
pub fn fourstep_smoke() -> bool {
    use crate::rdfft::engine::{self, EngineConfig};
    use crate::rdfft::simd;

    let n = 1usize << 14;
    let b = 2usize;
    let plan = cached(n);
    let x: Vec<f32> = (0..n * b).map(|i| ((i * 47 + 29) % 107) as f32 / 53.0 - 1.0).collect();
    let four_cfg = EngineConfig { fourstep_threshold: 1, ..EngineConfig::new() };
    let direct_cfg = EngineConfig { fourstep_threshold: usize::MAX, ..EngineConfig::new() };
    let mut four = x.clone();
    let t0 = engine::tier_counts();
    engine::forward_batch_with(&plan, &mut four, &four_cfg);
    let t1 = engine::tier_counts();
    let mut direct = x.clone();
    engine::forward_batch_with(&plan, &mut direct, &direct_cfg);
    let mut ok = true;
    // The whole point of this smoke is to compare the two tiers — if the
    // "four-step" leg silently fell back to the direct sweep (the old
    // routing bug) it would compare direct against direct and pass
    // vacuously. Require the tier to have actually engaged.
    let engaged = t1.since(t0);
    debug_assert!(
        engaged.fourstep > 0 && engaged.fallback == 0,
        "fourstep smoke leg did not engage the four-step tier: {engaged:?}"
    );
    if engaged.fourstep == 0 || engaged.fallback > 0 {
        println!(
            "fourstep smoke: four-step leg fell back to the direct sweep \
             (fourstep={}, fallback={}) — tier routing is broken",
            engaged.fourstep, engaged.fallback
        );
        ok = false;
    }
    let mut worst = 0.0f32;
    // The twiddle-product rounding is absolute in the √n-scaled
    // intermediate magnitudes, so the bound carries the same √n factor
    // as the golden-suite tolerances (10× tighter than the oracle's).
    let tol = 1e-5 * (n as f32).sqrt();
    for i in 0..four.len() {
        let d = (four[i] - direct[i]).abs() / (1.0 + direct[i].abs());
        if d > worst {
            worst = d;
        }
        if d > tol {
            ok = false;
        }
    }
    engine::inverse_batch_with(&plan, &mut four, &four_cfg);
    let mut rt_worst = 0.0f32;
    for i in 0..four.len() {
        let d = (four[i] - x[i]).abs();
        if d > rt_worst {
            rt_worst = d;
        }
        if d > 1e-3 {
            ok = false;
        }
    }
    println!(
        "fourstep smoke: n={n} batch={b} arm={:?} | vs-direct worst rel {worst:.2e} \
         (tol {tol:.2e}) | roundtrip worst abs {rt_worst:.2e} (tol 1e-3) -> {}",
        simd::active(),
        if ok { "ok" } else { "FAIL" }
    );
    ok
}

/// Shared row sweep for the native multi-layer memory grid: a short
/// native-trainer run per method (FF / LoRA / circulant×backends) at
/// equal width, printing total peak, activation+gradient peak,
/// trainable-parameter count, final loss and throughput. Used by
/// [`table_native`] and by `examples/finetune_memory.rs`.
pub fn native_method_rows(d: usize, depth: usize, batch: usize, steps: usize, p: usize) {
    use crate::autograd::optim::OptimKind;
    use crate::autograd::stack::StackConfig;
    use crate::coordinator::native::measure_native_run;

    println!(
        "{:<16}{:>12}{:>16}{:>14}{:>14}{:>12}",
        "method", "peak(MiB)", "act+grad(MiB)", "trainable", "loss", "tok/s"
    );
    let mut methods = vec![Method::FullFinetune, Method::Lora { rank: 16.min(d / 4).max(1) }];
    for bk in BACKENDS {
        methods.push(Method::Circulant { backend: bk, p });
    }
    // The sequence-mixing workload at the same width: k = d/4 taps.
    methods.push(Method::LongConv { k: (d / 4).max(1) });
    for m in methods {
        let cfg = StackConfig { d, depth, ctx: 8, method: m, seed: 3, ..Default::default() };
        let r = measure_native_run(cfg, OptimKind::Sgd, 0.2, batch, steps);
        println!(
            "{:<16}{:>12.2}{:>16.3}{:>14}{:>14.4}{:>12.0}",
            r.method,
            r.peak_mib(),
            r.activation_grad_peak() as f64 / (1024.0 * 1024.0),
            r.trainable_params,
            r.final_loss,
            r.tokens_per_sec,
        );
    }
}

/// Native multi-layer Table-1-style grid: run the pure-Rust trainer for a
/// few steps per method at equal width and print total peak plus the
/// activation+gradient peak (the axis the paper's in-place claim is
/// about). The circulant rdFFT row must sit strictly below full fine-tune
/// on that axis — `rust/tests/native_training.rs` asserts it.
pub fn table_native(fast: bool) {
    let (d, depth, batch, steps) = if fast { (128, 2, 8, 5) } else { (256, 3, 16, 10) };
    println!(
        "# Native multi-layer training memory — d={d}, depth={depth}, batch={batch}, {steps} steps (SGD)\n"
    );
    native_method_rows(d, depth, batch, steps, d / 4);
    println!(
        "\n(read: the rdFFT circulant row's act+grad column must sit strictly\n\
         below full fine-tune at equal width — the multi-layer extension of\n\
         Table 1, asserted in rust/tests/native_training.rs)"
    );
}

/// Measure the single-layer grid cell-by-cell and return machine-readable
/// rows — used by integration tests.
pub fn table1_cells(d: usize, batches: &[usize], p: usize) -> Vec<(String, usize, usize)> {
    let mut rows = Vec::new();
    for bk in BACKENDS {
        for &b in batches {
            let m = Method::Circulant { backend: bk, p };
            let cell = measure_single_layer(m, d, b, 1);
            rows.push((m.label(), b, cell.peak_bytes));
        }
    }
    rows
}
