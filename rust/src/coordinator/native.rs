//! Pure-Rust end-to-end trainer over the in-place engine — no PJRT, no
//! AOT artifacts, no Python.
//!
//! Where [`super::trainer::Trainer`] drives pre-compiled HLO through the
//! (stubbed) XLA runtime, `NativeTrainer` runs the whole loop natively:
//! synthetic corpus → context batches ([`crate::data::Batcher`]) →
//! [`crate::autograd::SpectralStack`] forward/backward (batch-major rdFFT
//! on the circulant hot path) → [`crate::autograd::OptimizerBank`]
//! updates — with `memtrack` category snapshots recorded every step, so a
//! run produces both a loss curve *and* the Table-1-style peak-memory
//! evidence for the multi-layer case.

use crate::autograd::optim::{OptimKind, OptimizerBank};
use crate::autograd::stack::{ShardArena, SpectralStack, StackConfig};
use crate::autograd::train::Method;
use crate::data::{Batcher, CorpusGen};
use crate::memtrack::{self, Category, Snapshot, NUM_CATEGORIES};
use crate::runtime::checkpoint::{self, TrainCheckpoint};
use crate::runtime::faultinject::FaultPlan;
use crate::runtime::pool::ExecCtx;
use anyhow::Result;
use std::io::Write;
use std::panic::AssertUnwindSafe;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// Native trainer configuration.
#[derive(Debug, Clone)]
pub struct NativeTrainerConfig {
    pub stack: StackConfig,
    pub optim: OptimKind,
    pub lr: f32,
    pub steps: usize,
    pub batch: usize,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub corpus_bytes: usize,
    pub seed: u64,
    pub log_csv: Option<PathBuf>,
    /// Print progress lines at eval points.
    pub verbose: bool,
    /// Data-parallel worker lanes. `0` = the classic serial step;
    /// `N >= 1` = the sharded step on a dedicated `ExecCtx` with `N`
    /// lanes (`N - 1` pool workers + the submitting thread). The shard
    /// structure is fixed, so every `N >= 1` produces **bit-identical**
    /// losses and parameters — `N` only changes wall-clock.
    pub threads: usize,
    /// Directory for crash-safe checkpoints; `None` disables
    /// checkpointing entirely (zero extra allocations on the step path).
    pub checkpoint_dir: Option<PathBuf>,
    /// Save a checkpoint every this many steps (and at the final step).
    /// `0` disables periodic saves even with a directory set.
    pub checkpoint_every: usize,
    /// Retention: keep only the newest K checkpoint files.
    pub checkpoint_keep: usize,
    /// Resume from the newest valid checkpoint in `checkpoint_dir`
    /// before training (fresh start if the directory is empty).
    pub resume: bool,
    /// Deterministic fault schedule (empty in normal runs). Shared with
    /// the run's `ExecCtx` so shard jobs consult the same plan instance.
    pub faults: Arc<FaultPlan>,
    /// Heterogeneous tower: block `k` uses `block_methods[k]` instead of
    /// `stack.method` (length must equal `stack.depth`). `None` = the
    /// classic uniform stack. Used by `--layer mixed` (circulant blocks
    /// with a long-conv top block) and the determinism suites.
    pub block_methods: Option<Vec<Method>>,
}

impl Default for NativeTrainerConfig {
    fn default() -> Self {
        NativeTrainerConfig {
            stack: StackConfig::default(),
            optim: OptimKind::Sgd,
            lr: 0.2,
            steps: 150,
            batch: 16,
            eval_every: 25,
            eval_batches: 4,
            corpus_bytes: 256 * 1024,
            seed: 0,
            log_csv: None,
            verbose: true,
            threads: 0,
            checkpoint_dir: None,
            checkpoint_every: 25,
            checkpoint_keep: 3,
            resume: false,
            faults: Arc::new(FaultPlan::none()),
            block_methods: None,
        }
    }
}

impl NativeTrainerConfig {
    /// Canonical string of every knob that shapes the training
    /// trajectory. A checkpoint records it at save time; resume refuses a
    /// checkpoint whose fingerprint differs — silently continuing a
    /// different run's trajectory would be corruption, not resumption.
    ///
    /// Deliberately **excluded**: `threads` (any lane count of the
    /// sharded step is bit-identical, so `--threads 4` may resume a
    /// `--threads 1` run), `verbose`, `log_csv`, and the checkpoint knobs
    /// themselves. The step-algorithm *class* (sharded vs classic) IS
    /// included: the two regroup float sums differently.
    ///
    /// The eval schedule is included because evaluation round-trips
    /// circulant parameters through the frequency domain between steps,
    /// which perturbs the trajectory at the ULP level — two runs only
    /// replay identically when they eval at the same steps.
    /// True when every block of the configured tower has shard hooks (the
    /// precondition for the data-parallel step).
    fn tower_supports_shard_exec(&self) -> bool {
        match &self.block_methods {
            Some(ms) => ms.iter().all(|m| m.supports_shard_exec()),
            None => self.stack.method.supports_shard_exec(),
        }
    }

    pub fn fingerprint(&self) -> String {
        let algo = if self.threads > 0 && self.tower_supports_shard_exec() {
            "sharded"
        } else {
            "classic"
        };
        // A uniform stack keeps the exact historical fingerprint string;
        // only heterogeneous towers append their block list, so old
        // checkpoints stay resumable.
        let blocks = match &self.block_methods {
            Some(ms) => format!(
                ";blocks={}",
                ms.iter().map(|m| m.label()).collect::<Vec<_>>().join("+")
            ),
            None => String::new(),
        };
        format!(
            "v1;algo={algo};d={};depth={};vocab={};ctx={};method={};mseed={};\
             optim={:?};lr={:08x};batch={};seed={};corpus={};eval={}x{}{blocks}",
            self.stack.d,
            self.stack.depth,
            self.stack.vocab,
            self.stack.ctx,
            self.stack.method.label(),
            self.stack.seed,
            self.optim,
            self.lr.to_bits(),
            self.batch,
            self.seed,
            self.corpus_bytes,
            self.eval_every,
            self.eval_batches,
        )
    }
}

/// Summary of a finished native run, including the memory evidence.
#[derive(Debug, Clone)]
pub struct NativeReport {
    pub method: String,
    pub steps: usize,
    pub first_loss: f32,
    pub final_loss: f32,
    /// Mean loss over the first `min(10, steps/2)` steps (robust trend
    /// anchor; the head/tail windows are disjoint for runs of >= 2 steps).
    pub head_loss: f32,
    /// Mean loss over the last `min(10, steps/2)` steps.
    pub tail_loss: f32,
    pub final_eval_loss: Option<f32>,
    pub tokens_per_sec: f64,
    pub losses: Vec<(usize, f32)>,
    /// Peak tracked bytes over the whole run (params + optimizer state +
    /// activations + gradients).
    pub peak_bytes: usize,
    /// Category composition at the peak moment.
    pub at_peak: [usize; NUM_CATEGORIES],
    /// Independent per-category peaks over the run.
    pub peak_by_cat: [usize; NUM_CATEGORIES],
    pub trainable_params: usize,
    pub optimizer_state_bytes: usize,
    /// Data-parallel lanes the run used (0 = classic serial step).
    pub threads: usize,
    /// Steps that lost their pool fan-out to a panic and completed on the
    /// scoped-serial fallback instead (0 in healthy runs).
    pub degraded_steps: usize,
    /// `Some(step)` when an injected `halt@STEP` fault stopped the run
    /// before executing that step (in-process simulated kill).
    pub halted_at: Option<usize>,
    /// `Some(step)` when the run resumed from a checkpoint taken after
    /// that step (its loss curve starts at `step + 1`).
    pub resumed_from: Option<usize>,
    /// Checkpoints successfully written during the run.
    pub checkpoints_written: usize,
}

impl NativeReport {
    pub fn peak_mib(&self) -> f64 {
        self.peak_bytes as f64 / (1024.0 * 1024.0)
    }

    /// The step-state bytes the method itself is responsible for:
    /// activation/transient peak + gradient peak (the paper's
    /// "intermediates + gradients" axis, persistent weights excluded).
    pub fn activation_grad_peak(&self) -> usize {
        self.peak_by_cat[Category::Intermediates.index()]
            + self.peak_by_cat[Category::Gradients.index()]
    }

    /// True when the loss trend over the run is downward. A run too short
    /// to carry a trend (fewer than 2 steps: head and tail are the same
    /// sample) passes vacuously rather than failing unconditionally.
    pub fn loss_decreased(&self) -> bool {
        self.steps < 2 || self.tail_loss < self.head_loss
    }
}

/// The native training orchestrator.
pub struct NativeTrainer {
    cfg: NativeTrainerConfig,
    stack: SpectralStack,
    bank: OptimizerBank,
    /// `Some` when the run is data-parallel: the dedicated context whose
    /// pool the shard jobs run on ...
    exec: Option<ExecCtx>,
    /// ... and the pooled gradient-shard arena (allocated once, tracked
    /// under `Gradients`, reused every step).
    arena: Option<ShardArena>,
}

impl NativeTrainer {
    /// Build the model under a fresh `memtrack` scope, so the report's
    /// category breakdown covers exactly this trainer's tensors. Resets
    /// the calling thread's tracker: the caller must not hold live
    /// tracked objects (their later `Drop` would unbalance the
    /// accounting) — checked below in debug builds, where the stale
    /// `Drop` would otherwise panic far from the cause.
    pub fn new(cfg: NativeTrainerConfig) -> Self {
        debug_assert_eq!(
            memtrack::snapshot().current_total(),
            0,
            "NativeTrainer::new resets the thread-local memory tracker; \
             drop tracked tensors/operators before constructing one"
        );
        memtrack::reset();
        // Decide on data-parallel mode BEFORE building anything: a method
        // without shard support (fft/rfft circulant backends) falls back
        // to the classic serial step without ever spawning pool workers.
        let parallel = cfg.threads > 0 && cfg.tower_supports_shard_exec();
        let (stack, exec) = if parallel {
            // One ExecCtx governs the whole run: the blocks' engine
            // dispatch and the trainer's shard fan-out share its pool;
            // shard-arena scratch is charged to Gradients.
            let exec = ExecCtx::with_threads(cfg.threads)
                .with_category(Category::Gradients)
                .with_faults(cfg.faults.clone());
            let stack = match &cfg.block_methods {
                Some(ms) => {
                    SpectralStack::new_mixed_with_exec(cfg.stack.clone(), ms, exec.clone())
                }
                None => SpectralStack::with_exec(cfg.stack.clone(), exec.clone()),
            };
            (stack, Some(exec))
        } else {
            let stack = match &cfg.block_methods {
                Some(ms) => SpectralStack::new_mixed(cfg.stack.clone(), ms),
                None => SpectralStack::new(cfg.stack.clone()),
            };
            (stack, None)
        };
        let arena =
            exec.as_ref().map(|e| ShardArena::new(&stack, e.scratch_category()));
        let bank = OptimizerBank::new(cfg.optim, cfg.lr);
        NativeTrainer { cfg, stack, bank, exec, arena }
    }

    pub fn stack(&self) -> &SpectralStack {
        &self.stack
    }

    /// Mutable stack access (the crashtest compares final parameters via
    /// `export_params`, which needs `&mut` for the canonical-domain
    /// guarantee).
    pub fn stack_mut(&mut self) -> &mut SpectralStack {
        &mut self.stack
    }

    /// Assemble a complete trainer snapshot: parameters (canonical time
    /// domain via `for_each_param`), optimizer moments and step counters,
    /// the batcher's RNG cursor, and the config fingerprint.
    fn snapshot_state(&mut self, step: usize, fingerprint: &str, batcher: &Batcher) -> TrainCheckpoint {
        let (param_lens, params) = self.stack.export_params();
        let (optim_steps, optim_m, optim_v) = self.bank.export_state();
        TrainCheckpoint {
            step,
            fingerprint: fingerprint.to_string(),
            rng_state: batcher.rng_state(),
            param_lens,
            params,
            optim_steps,
            optim_m,
            optim_v,
        }
    }

    /// Run the loop; returns the report (loss curve + memory evidence).
    pub fn run(&mut self) -> Result<NativeReport> {
        let cfg = self.cfg.clone();
        let ctx = cfg.stack.ctx;
        let method = match &cfg.block_methods {
            Some(ms) => format!(
                "mixed[{}]",
                ms.iter().map(|m| m.label()).collect::<Vec<_>>().join("+")
            ),
            None => cfg.stack.method.label(),
        };
        let threads = self.exec.as_ref().map(|e| e.threads()).unwrap_or(0);
        if cfg.verbose {
            println!(
                "[train-native] method={method} d={} depth={} ctx={ctx} optim={} lr={} | {} trainable params",
                cfg.stack.d,
                cfg.stack.depth,
                cfg.optim.name(),
                cfg.lr,
                self.stack.num_trainable(),
            );
            if threads > 0 {
                let arena_kib = self
                    .arena
                    .as_ref()
                    .map(|a| a.tracked_bytes() / 1024)
                    .unwrap_or(0);
                println!(
                    "[train-native] data-parallel: {threads} lane(s), fixed-shard \
                     deterministic reduction ({arena_kib} KiB grad-shard arena)"
                );
            } else if cfg.threads > 0 {
                println!(
                    "[train-native] --threads {} requested but a block lacks shard \
                     support (fft/rfft backends are out-of-place); using the serial step",
                    cfg.threads
                );
            }
        }
        let text = CorpusGen::new(cfg.seed).text(cfg.corpus_bytes);
        // try_new: a corpus too small for the context window is a typed,
        // propagated error (clean CLI failure), not a panic.
        let mut batcher = Batcher::try_new(&text, cfg.batch, ctx.max(2), cfg.seed + 1)?;
        // Held-out corpus only when evaluation will actually run.
        let eval_enabled = cfg.eval_every > 0 && cfg.eval_batches > 0;
        let eval_batcher = if eval_enabled {
            let eval_text = CorpusGen::new(cfg.seed + 7777).text(64 * 1024);
            Some(Batcher::try_new(&eval_text, cfg.batch, ctx.max(2), 0)?)
        } else {
            None
        };

        // ---- Resume (before anything mutates trainer state) ----------
        let fp = cfg.fingerprint();
        let mut start_step = 1usize;
        let mut resumed_from = None;
        if cfg.resume {
            let dir = cfg.checkpoint_dir.as_ref().ok_or_else(|| {
                anyhow::anyhow!("resume requested but no checkpoint directory configured")
            })?;
            match checkpoint::latest_valid(dir, &fp) {
                Ok(Some((ck, notices))) => {
                    for n in &notices {
                        eprintln!("[train-native] {n}");
                    }
                    self.stack
                        .import_params(&ck.params)
                        .map_err(|e| anyhow::anyhow!("restoring parameters: {e}"))?;
                    self.bank
                        .import_state(
                            &ck.optim_steps,
                            &ck.optim_m,
                            &ck.optim_v,
                            &ck.param_lens,
                        )
                        .map_err(|e| anyhow::anyhow!("restoring optimizer state: {e}"))?;
                    batcher.restore_rng_state(ck.rng_state);
                    start_step = ck.step + 1;
                    resumed_from = Some(ck.step);
                    if cfg.verbose {
                        println!(
                            "[train-native] resumed from step {} ({})",
                            ck.step,
                            dir.display()
                        );
                    }
                }
                Ok(None) => {
                    if cfg.verbose {
                        println!(
                            "[train-native] no valid checkpoint in {}; starting fresh",
                            dir.display()
                        );
                    }
                }
                // FingerprintMismatch (or an unreadable directory): a
                // clear, propagated error rather than a silent restart.
                Err(e) => return Err(anyhow::anyhow!("resume failed: {e}")),
            }
        }

        // Note: a resumed run truncates and rewrites the CSV from its
        // resume point (open_csv truncates) — the log restarts with the
        // run, which keeps the file internally consistent.
        let mut csv = match &cfg.log_csv {
            Some(p) => Some(super::open_csv(
                p,
                "step,loss,eval_loss,tokens_per_sec,peak_mib,weights_mib,trainable_mib,gradients_mib,intermediates_mib,other_mib,checkpoint_mib",
            )?),
            None => None,
        };

        memtrack::reset_peak();
        let mut losses = Vec::with_capacity(cfg.steps);
        let mut final_eval = None;
        let mut degraded_steps = 0usize;
        let mut halted_at = None;
        let mut checkpoints_written = 0usize;
        let save_every = cfg.checkpoint_every;
        let t0 = Instant::now();
        let mut tokens_seen = 0usize;
        // Wall time spent inside evaluation, excluded from throughput so
        // eval-enabled and eval-disabled runs report the same tok/s.
        let mut eval_secs = 0.0f64;

        for step in start_step..=cfg.steps {
            // Scope the fault plan to this step, then apply any
            // process-level faults scheduled here.
            cfg.faults.begin_step(step);
            if cfg.faults.take_halt(step) {
                eprintln!("[faultinject] halt: stopping before step {step}");
                halted_at = Some(step);
                break;
            }
            if cfg.faults.take_abort(step) {
                eprintln!("[faultinject] abort: killing the process at step {step}");
                std::process::abort();
            }
            // Typed BatchError surfaces as a clean CLI failure on tiny
            // corpora instead of a panic inside the sampler.
            let (ctxs, labels) = batcher.next_context_batch(ctx)?;
            // The sharded step fans out on the stack's own ExecCtx (the
            // trainer installed it at construction).
            let loss = match self.arena.as_mut() {
                Some(arena) => {
                    match self.stack.train_step_sharded(&ctxs, &labels, &mut self.bank, arena) {
                        Ok(l) => l,
                        Err(p) => {
                            // Graceful degradation: the panic surfaced
                            // before any reduction or optimizer mutation,
                            // so retrying the whole step on the scoped-
                            // serial fallback reproduces the unfailed
                            // step bit-exactly. A second failure is a
                            // real defect — hard-fail.
                            degraded_steps += 1;
                            eprintln!(
                                "[train-native] step {step}: pool shard job panicked \
                                 ({}); discarding shard buffers and retrying this \
                                 step on the serial fallback",
                                p.message()
                            );
                            let retry = std::panic::catch_unwind(AssertUnwindSafe(|| {
                                self.stack.train_step_sharded_serial(
                                    &ctxs,
                                    &labels,
                                    &mut self.bank,
                                    arena,
                                )
                            }));
                            match retry {
                                Ok(l) => l,
                                Err(payload) => {
                                    let msg = payload
                                        .downcast_ref::<&str>()
                                        .map(|s| s.to_string())
                                        .or_else(|| {
                                            payload.downcast_ref::<String>().cloned()
                                        })
                                        .unwrap_or_else(|| "unknown panic".to_string());
                                    anyhow::bail!(
                                        "step {step} failed in the worker pool ({}) and \
                                         again on the serial fallback ({msg}); giving up",
                                        p.message()
                                    );
                                }
                            }
                        }
                    }
                }
                None => self.stack.train_step(&ctxs, &labels, &mut self.bank),
            };
            tokens_seen += cfg.batch * ctx;
            losses.push((step, loss));

            // Checkpoint immediately after the update and BEFORE eval:
            // parameters are guaranteed canonical time-domain here, so
            // the export adds zero perturbation, and a resumed run
            // replays the identical eval/transform sequence for every
            // later step — the placement bit-identical resume depends on.
            if let Some(dir) = cfg.checkpoint_dir.as_ref() {
                if save_every > 0 && (step % save_every == 0 || step == cfg.steps) {
                    let ck = self.snapshot_state(step, &fp, &batcher);
                    match ck.save(dir, cfg.checkpoint_keep, &cfg.faults) {
                        Ok(path) => {
                            checkpoints_written += 1;
                            if cfg.verbose {
                                println!(
                                    "[train-native] checkpoint: {}",
                                    path.display()
                                );
                            }
                        }
                        // A failed save must not kill training — warn
                        // and continue; the previous checkpoints remain.
                        Err(e) => eprintln!(
                            "[train-native] warning: checkpoint at step {step} \
                             failed ({e}); continuing"
                        ),
                    }
                }
            }
            let snap = memtrack::snapshot();

            let do_eval = eval_enabled && (step % cfg.eval_every == 0 || step == cfg.steps);
            let mut eval_loss = None;
            if do_eval {
                let te = Instant::now();
                let eb = eval_batcher.as_ref().expect("eval_enabled implies a batcher");
                let mut acc = 0.0f32;
                for i in 0..cfg.eval_batches {
                    let (et, el) = eb.eval_context_batch(i, ctx)?;
                    acc += self.stack.eval_loss(&et, &el);
                }
                let e = acc / cfg.eval_batches as f32;
                eval_secs += te.elapsed().as_secs_f64();
                eval_loss = Some(e);
                final_eval = Some(e);
                if cfg.verbose {
                    println!(
                        "[train-native] step {step:>5}  loss {loss:.4}  eval {e:.4}  peak {:.2} MiB  {:.0} tok/s",
                        snap.peak_mib(),
                        tokens_seen as f64 / (t0.elapsed().as_secs_f64() - eval_secs).max(1e-9),
                    );
                }
            }
            if let Some(f) = csv.as_mut() {
                let mib = |b: usize| b as f64 / (1024.0 * 1024.0);
                writeln!(
                    f,
                    "{step},{loss},{},{:.1},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4}",
                    eval_loss.map(|e| e.to_string()).unwrap_or_default(),
                    tokens_seen as f64 / (t0.elapsed().as_secs_f64() - eval_secs).max(1e-9),
                    snap.peak_mib(),
                    mib(snap.current[Category::Weights.index()]),
                    mib(snap.current[Category::Trainable.index()]),
                    mib(snap.current[Category::Gradients.index()]),
                    mib(snap.current[Category::Intermediates.index()]),
                    mib(snap.current[Category::Other.index()]),
                    mib(snap.current[Category::Checkpoint.index()]),
                )?;
            }
        }

        // Deactivate the fault plan: nothing fires outside the loop.
        cfg.faults.begin_step(0);
        let snap: Snapshot = memtrack::snapshot();
        let secs = (t0.elapsed().as_secs_f64() - eval_secs).max(1e-9);
        // Trend windows: first/last w steps with w = min(10, steps/2), so
        // the windows never overlap for runs of >= 2 steps (single-step
        // runs share the one sample; loss_decreased() passes vacuously).
        let w = (losses.len() / 2).min(10).max(1);
        let head = losses.iter().take(w).map(|&(_, l)| l as f64).sum::<f64>() / w as f64;
        let tail = losses.iter().rev().take(w).map(|&(_, l)| l as f64).sum::<f64>() / w as f64;
        Ok(NativeReport {
            method,
            steps: cfg.steps,
            first_loss: losses.first().map(|&(_, l)| l).unwrap_or(f32::NAN),
            final_loss: losses.last().map(|&(_, l)| l).unwrap_or(f32::NAN),
            head_loss: head as f32,
            tail_loss: tail as f32,
            final_eval_loss: final_eval,
            tokens_per_sec: tokens_seen as f64 / secs,
            losses,
            peak_bytes: snap.peak_total,
            at_peak: snap.at_peak,
            peak_by_cat: snap.peak_by_cat,
            trainable_params: self.stack.num_trainable(),
            optimizer_state_bytes: self.bank.state_bytes(),
            threads,
            degraded_steps,
            halted_at,
            resumed_from,
            checkpoints_written,
        })
    }
}

/// Convenience: run a short quiet native training and return the report —
/// the measurement entry point used by tests, the memory example, and
/// `repro table-native`.
pub fn measure_native_run(
    stack: StackConfig,
    optim: OptimKind,
    lr: f32,
    batch: usize,
    steps: usize,
) -> NativeReport {
    let cfg = NativeTrainerConfig {
        stack,
        optim,
        lr,
        steps,
        batch,
        eval_every: 0,
        eval_batches: 0,
        corpus_bytes: 32 * 1024,
        seed: 7,
        log_csv: None,
        verbose: false,
        threads: 0,
        ..Default::default()
    };
    let mut t = NativeTrainer::new(cfg);
    t.run().expect("native run cannot fail: no CSV path and a 32 KiB corpus")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::layers::Backend;
    use crate::autograd::train::Method;

    fn small_stack(method: Method) -> StackConfig {
        StackConfig { d: 32, depth: 2, ctx: 4, method, seed: 1, ..Default::default() }
    }

    #[test]
    fn native_run_reports_losses_and_memory() {
        let r = measure_native_run(
            small_stack(Method::Circulant { backend: Backend::RdFft, p: 8 }),
            OptimKind::Sgd,
            0.2,
            8,
            30,
        );
        assert_eq!(r.losses.len(), 30);
        assert!(r.peak_bytes > 0);
        assert!(r.trainable_params > 0);
        assert!(r.tokens_per_sec > 0.0);
        assert!(r.at_peak.iter().sum::<usize>() == r.peak_bytes);
    }

    #[test]
    fn sgd_has_no_optimizer_state_adam_does() {
        let sgd = measure_native_run(
            small_stack(Method::Circulant { backend: Backend::RdFft, p: 8 }),
            OptimKind::Sgd,
            0.2,
            4,
            3,
        );
        assert_eq!(sgd.optimizer_state_bytes, 0);
        let adam = measure_native_run(
            small_stack(Method::Circulant { backend: Backend::RdFft, p: 8 }),
            OptimKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            0.01,
            4,
            3,
        );
        assert_eq!(adam.optimizer_state_bytes, 2 * adam.trainable_params * 4);
    }

    #[test]
    fn threaded_run_bit_identical_to_single_lane() {
        let mk = |threads: usize| NativeTrainerConfig {
            stack: small_stack(Method::Circulant { backend: Backend::RdFft, p: 8 }),
            optim: OptimKind::Sgd,
            lr: 0.2,
            steps: 10,
            batch: 8,
            eval_every: 0,
            eval_batches: 0,
            corpus_bytes: 16 * 1024,
            seed: 5,
            log_csv: None,
            verbose: false,
            threads,
            ..Default::default()
        };
        let r1 = {
            let mut t = NativeTrainer::new(mk(1));
            t.run().unwrap()
        };
        let r2 = {
            let mut t = NativeTrainer::new(mk(2));
            t.run().unwrap()
        };
        assert_eq!(r2.threads, 2);
        assert_eq!(r1.threads, 1);
        assert_eq!(r1.losses, r2.losses, "loss curves must be bit-identical");
        assert_eq!(r1.final_loss.to_bits(), r2.final_loss.to_bits());
    }

    #[test]
    fn mixed_tower_trains_sharded_and_uniform_fingerprint_is_unchanged() {
        // Uniform stacks must keep the exact historical fingerprint (no
        // ";blocks=" suffix), or every old checkpoint stops resuming.
        let uniform = NativeTrainerConfig {
            stack: small_stack(Method::Circulant { backend: Backend::RdFft, p: 8 }),
            ..Default::default()
        };
        assert!(!uniform.fingerprint().contains(";blocks="));
        // The --layer mixed tower: circulant blocks + a long-conv top
        // block, trained data-parallel (every block has shard hooks).
        let cfg = NativeTrainerConfig {
            stack: StackConfig { d: 32, depth: 3, ctx: 4, seed: 1, ..Default::default() },
            block_methods: Some(vec![
                Method::Circulant { backend: Backend::RdFft, p: 8 },
                Method::Circulant { backend: Backend::RdFft, p: 8 },
                Method::LongConv { k: 9 },
            ]),
            steps: 20,
            batch: 8,
            eval_every: 0,
            eval_batches: 0,
            corpus_bytes: 16 * 1024,
            verbose: false,
            threads: 2,
            ..Default::default()
        };
        let fp = cfg.fingerprint();
        assert!(fp.contains(";blocks=") && fp.contains("longconv_k=9"), "{fp}");
        assert!(fp.contains("algo=sharded"), "{fp}");
        let mut t = NativeTrainer::new(cfg);
        let r = t.run().unwrap();
        assert_eq!(r.threads, 2, "a long-conv block must not break shard support");
        assert_eq!(r.losses.len(), 20);
        assert!(r.loss_decreased(), "mixed tower loss must trend down");
    }

    #[test]
    fn unsupported_backend_falls_back_to_serial_step() {
        // fft backend has no shard hooks: --threads must degrade
        // gracefully to the classic step, not panic.
        let cfg = NativeTrainerConfig {
            stack: small_stack(Method::Circulant { backend: Backend::Fft, p: 8 }),
            steps: 3,
            batch: 4,
            eval_every: 0,
            eval_batches: 0,
            corpus_bytes: 16 * 1024,
            verbose: false,
            threads: 2,
            ..Default::default()
        };
        let mut t = NativeTrainer::new(cfg);
        let r = t.run().unwrap();
        assert_eq!(r.threads, 0, "fallback must report the serial step");
        assert_eq!(r.losses.len(), 3);
    }

    #[test]
    fn csv_log_has_expected_schema() {
        let path = std::env::temp_dir()
            .join(format!("rdfft_native_csv_{}.csv", std::process::id()));
        let cfg = NativeTrainerConfig {
            stack: small_stack(Method::Circulant { backend: Backend::RdFft, p: 8 }),
            steps: 5,
            batch: 4,
            eval_every: 5,
            eval_batches: 2,
            corpus_bytes: 16 * 1024,
            log_csv: Some(path.clone()),
            verbose: false,
            ..Default::default()
        };
        let mut t = NativeTrainer::new(cfg);
        let _ = t.run().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        let header = lines.next().unwrap();
        assert!(header.starts_with("step,loss,eval_loss,tokens_per_sec,peak_mib"));
        assert_eq!(lines.count(), 5, "one row per step");
        let _ = std::fs::remove_file(&path);
    }
}
