//! Out-of-place standard complex FFT — the `torch.fft.fft` analogue.
//!
//! Faithful to how the fft baseline behaves inside a PyTorch circulant
//! layer: the real input is promoted to a fresh complex buffer (2n reals,
//! tracked as `Intermediates`), an iterative radix-2 Cooley–Tukey runs on
//! it, and the caller receives the (newly allocated) complex result. The
//! transform itself is the same O(n log n) butterfly network as rdFFT — the
//! difference under measurement is purely the allocation/dtype behaviour,
//! which is the paper's point.

use crate::memtrack::{self, Category};

/// Plain complex number (two f32s, like `torch.complex64` elements).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Complex {
    pub re: f32,
    pub im: f32,
}

impl Complex {
    #[inline]
    pub fn new(re: f32, im: f32) -> Self {
        Complex { re, im }
    }
    #[inline]
    pub fn mul(self, o: Complex) -> Complex {
        Complex::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }
    #[inline]
    pub fn conj(self) -> Complex {
        Complex::new(self.re, -self.im)
    }
    #[inline]
    pub fn add(self, o: Complex) -> Complex {
        Complex::new(self.re + o.re, self.im + o.im)
    }
    #[inline]
    pub fn sub(self, o: Complex) -> Complex {
        Complex::new(self.re - o.re, self.im - o.im)
    }
}

/// A heap complex buffer registered with the memory tracker (8 bytes per
/// element, like complex64).
pub struct ComplexVec {
    data: Vec<Complex>,
    cat: Category,
}

impl ComplexVec {
    pub fn zeros(len: usize, cat: Category) -> Self {
        memtrack::on_alloc(len * 8, cat);
        ComplexVec { data: vec![Complex::default(); len], cat }
    }
    pub fn from_real(x: &[f32], cat: Category) -> Self {
        memtrack::on_alloc(x.len() * 8, cat);
        ComplexVec { data: x.iter().map(|&v| Complex::new(v, 0.0)).collect(), cat }
    }
}

impl std::ops::Deref for ComplexVec {
    type Target = [Complex];
    fn deref(&self) -> &[Complex] {
        &self.data
    }
}
impl std::ops::DerefMut for ComplexVec {
    fn deref_mut(&mut self) -> &mut [Complex] {
        &mut self.data
    }
}
impl Drop for ComplexVec {
    fn drop(&mut self) {
        memtrack::on_free(self.data.len() * 8, self.cat);
    }
}
impl Clone for ComplexVec {
    fn clone(&self) -> Self {
        memtrack::on_alloc(self.data.len() * 8, self.cat);
        ComplexVec { data: self.data.clone(), cat: self.cat }
    }
}

/// Per-size twiddle cache — real FFT libraries (FFTW plans, cuFFT plans,
/// torch's cached cuFFT handles) never recompute trig per call, so the
/// baseline must not either (it would make Table 3 unfairly favourable
/// to rdFFT). Stages are concatenated: stage with half-block m stores
/// W_{2m}^k for k = 0..m-1.
fn twiddle_table(n: usize, inverse: bool) -> std::sync::Arc<Vec<Complex>> {
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex, OnceLock};
    static CACHE: OnceLock<Mutex<HashMap<(usize, bool), Arc<Vec<Complex>>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    // Poison recovery per the plan-cache policy: entries are inserted
    // whole (`or_insert_with` of a finished Arc), so the map is valid
    // even if a racing thread panicked — don't fail every later baseline
    // transform over it.
    let mut map = cache.lock().unwrap_or_else(|p| p.into_inner());
    map.entry((n, inverse))
        .or_insert_with(|| {
            let sign = if inverse { 1.0f64 } else { -1.0f64 };
            let mut tw = Vec::with_capacity(n.max(2) - 1);
            let mut m = 1usize;
            while m < n {
                let step = std::f64::consts::TAU / (2 * m) as f64 * sign;
                for k in 0..m {
                    let th = step * k as f64;
                    tw.push(Complex::new(th.cos() as f32, th.sin() as f32));
                }
                m *= 2;
            }
            Arc::new(tw)
        })
        .clone()
}

/// Iterative radix-2 Cooley–Tukey on a complex slice (in place on the
/// complex buffer; the *allocation* happened when the buffer was created).
fn fft_complex(buf: &mut [Complex], inverse: bool) {
    let n = buf.len();
    assert!(n.is_power_of_two() && n >= 2);
    let log2n = n.trailing_zeros();
    // bit reversal
    for i in 0..n {
        let j = ((i as u32).reverse_bits() >> (32 - log2n)) as usize;
        if i < j {
            buf.swap(i, j);
        }
    }
    let tw = twiddle_table(n, inverse);
    let mut m = 1usize;
    let mut toff = 0usize;
    while m < n {
        let stage = &tw[toff..toff + m];
        for s in (0..n).step_by(2 * m) {
            // SAFETY: s + 2m <= n by loop bounds; k < m.
            unsafe {
                let blk = buf.get_unchecked_mut(s..s + 2 * m);
                for (k, w) in stage.iter().enumerate() {
                    let t = blk.get_unchecked(m + k).mul(*w);
                    let e = *blk.get_unchecked(k);
                    *blk.get_unchecked_mut(k) = e.add(t);
                    *blk.get_unchecked_mut(m + k) = e.sub(t);
                }
            }
        }
        toff += m;
        m *= 2;
    }
    if inverse {
        let inv_n = 1.0 / n as f32;
        for v in buf {
            v.re *= inv_n;
            v.im *= inv_n;
        }
    }
}

/// `torch.fft.fft(x)` for real `x`: allocates a 2n-real complex buffer,
/// promotes, transforms. The returned buffer is tracked.
pub fn fft_out_of_place(x: &[f32], cat: Category) -> ComplexVec {
    let mut buf = ComplexVec::from_real(x, cat);
    fft_complex(&mut buf, false);
    buf
}

/// `torch.fft.fft` over an existing complex tensor (allocates the output
/// copy, as the out-of-place torch op does).
pub fn fft_complex_out_of_place(x: &ComplexVec, cat: Category) -> ComplexVec {
    let mut out = ComplexVec::zeros(x.len(), cat);
    out.data.copy_from_slice(x);
    fft_complex(&mut out, false);
    out
}

/// `torch.fft.ifft(x)`: allocates the complex output, transforms.
pub fn ifft_out_of_place(x: &ComplexVec, cat: Category) -> ComplexVec {
    let mut out = ComplexVec::zeros(x.len(), cat);
    out.data.copy_from_slice(x);
    fft_complex(&mut out, true);
    out
}

/// Extract the real part into a freshly allocated real buffer
/// (`torch.real(...)` materialization at the end of Eq. 4).
pub fn real_part(x: &ComplexVec, cat: Category) -> crate::memtrack::TrackedVec {
    let mut out = crate::memtrack::TrackedVec::zeros(x.len(), cat);
    for (o, c) in out.iter_mut().zip(x.iter()) {
        *o = c.re;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::naive_dft;

    #[test]
    fn matches_naive_dft() {
        let x: Vec<f32> = (0..32).map(|i| ((i * 17 + 5) % 23) as f32 / 11.0 - 1.0).collect();
        let spec = fft_out_of_place(&x, Category::Other);
        let want = naive_dft(&x);
        for k in 0..32 {
            assert!((spec[k].re - want[k].0).abs() < 1e-3, "k={k}");
            assert!((spec[k].im - want[k].1).abs() < 1e-3, "k={k}");
        }
    }

    #[test]
    fn ifft_inverts_fft() {
        let x: Vec<f32> = (0..64).map(|i| (i as f32 * 0.3).cos()).collect();
        let spec = fft_out_of_place(&x, Category::Other);
        let back = ifft_out_of_place(&spec, Category::Other);
        for i in 0..64 {
            assert!((back[i].re - x[i]).abs() < 1e-4);
            assert!(back[i].im.abs() < 1e-4);
        }
    }

    #[test]
    fn allocations_are_tracked() {
        memtrack::reset();
        let x = vec![1.0f32; 128];
        {
            let _spec = fft_out_of_place(&x, Category::Intermediates);
            // 128 complex = 1024 bytes live
            assert_eq!(memtrack::snapshot().current_total(), 128 * 8);
        }
        assert_eq!(memtrack::snapshot().current_total(), 0);
        assert_eq!(memtrack::snapshot().peak_total, 128 * 8);
    }
}
