//! Baseline FFT implementations the paper compares against.
//!
//! * [`complex_fft`] — analogue of `torch.fft.fft/ifft`: out-of-place
//!   standard complex FFT. A real length-`n` input is first *promoted to a
//!   complex buffer of `2n` reals* (allocation), transformed, and every
//!   intermediate in a circulant layer stays complex.
//! * [`rfft`] — analogue of `torch.fft.rfft/irfft`: Hermitian-symmetric
//!   real FFT returning `n/2+1` complex values in a freshly allocated
//!   `n+2`-real buffer; the inverse allocates the `n`-real output.
//! * [`naive_dft`] — O(n²) f64 direct DFT, the accuracy oracle for Table 3.
//!
//! The *allocation profile* of these baselines is the point: their extra
//! buffers are tracked by [`crate::memtrack`] and produce the fft/rfft rows
//! of Table 1 and Fig 2, while rdFFT's rows stay allocation-free.

pub mod complex_fft;
pub mod rfft;

pub use complex_fft::{fft_out_of_place, ifft_out_of_place, Complex};
pub use rfft::{irfft_alloc, rfft_alloc};

/// O(n²) direct DFT of a real signal, computed in f64 — the numerical
/// ground truth used by the Table 3 accuracy rows. Returns `(re, im)`
/// pairs for all `n` bins.
pub fn naive_dft(x: &[f32]) -> Vec<(f32, f32)> {
    let n = x.len();
    let mut out = Vec::with_capacity(n);
    for k in 0..n {
        let mut re = 0.0f64;
        let mut im = 0.0f64;
        for (i, &v) in x.iter().enumerate() {
            let theta = -std::f64::consts::TAU * (k as f64) * (i as f64) / (n as f64);
            re += v as f64 * theta.cos();
            im += v as f64 * theta.sin();
        }
        out.push((re as f32, im as f32));
    }
    out
}

/// O(n²) direct inverse DFT (f64) of a full complex spectrum; returns the
/// complex result (imaginary parts ≈ 0 for Hermitian input).
pub fn naive_idft(spec: &[(f32, f32)]) -> Vec<(f32, f32)> {
    let n = spec.len();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut re = 0.0f64;
        let mut im = 0.0f64;
        for (k, &(sr, si)) in spec.iter().enumerate() {
            let theta = std::f64::consts::TAU * (k as f64) * (i as f64) / (n as f64);
            let (c, s) = (theta.cos(), theta.sin());
            re += sr as f64 * c - si as f64 * s;
            im += sr as f64 * s + si as f64 * c;
        }
        out.push(((re / n as f64) as f32, (im / n as f64) as f32));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_dft_of_impulse_is_flat() {
        let mut x = vec![0.0f32; 8];
        x[0] = 1.0;
        let spec = naive_dft(&x);
        for (re, im) in spec {
            assert!((re - 1.0).abs() < 1e-6);
            assert!(im.abs() < 1e-6);
        }
    }

    #[test]
    fn naive_idft_inverts_naive_dft() {
        let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.7).sin()).collect();
        let spec = naive_dft(&x);
        let back = naive_idft(&spec);
        for i in 0..16 {
            assert!((back[i].0 - x[i]).abs() < 1e-5);
            assert!(back[i].1.abs() < 1e-5);
        }
    }

    #[test]
    fn naive_dft_hermitian_for_real_input() {
        let x: Vec<f32> = (0..12).map(|i| (i * i % 7) as f32 - 3.0).collect();
        let spec = naive_dft(&x);
        for k in 1..6 {
            assert!((spec[k].0 - spec[12 - k].0).abs() < 1e-4);
            assert!((spec[k].1 + spec[12 - k].1).abs() < 1e-4);
        }
    }
}
