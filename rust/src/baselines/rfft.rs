//! Out-of-place real FFT — the `torch.fft.rfft/irfft` analogue.
//!
//! rfft maps `n` reals to `n/2+1` complex values occupying `n+2` reals —
//! the **dimension mismatch** the paper's §1/§3.1 is about: the output
//! cannot live in the input's buffer, so every call allocates. We compute
//! the spectrum with the same butterfly core as rdFFT (numerics identical)
//! and then *materialize* it into a freshly allocated rfft-format buffer,
//! reproducing exactly the allocation behaviour the paper measures.

use crate::memtrack::{self, Category};
use crate::rdfft::{irdfft_inplace, layout, plan::cached, rdfft_inplace};

/// rfft output: `n/2+1` complex coefficients in `n+2` tracked reals.
pub struct RfftVec {
    data: Vec<(f32, f32)>,
    cat: Category,
}

impl RfftVec {
    pub fn zeros(half_plus_one: usize, cat: Category) -> Self {
        memtrack::on_alloc(half_plus_one * 8, cat);
        RfftVec { data: vec![(0.0, 0.0); half_plus_one], cat }
    }

    /// Number of real scalars this buffer occupies (`n + 2`).
    pub fn real_len(&self) -> usize {
        self.data.len() * 2
    }
}

impl std::ops::Deref for RfftVec {
    type Target = [(f32, f32)];
    fn deref(&self) -> &[(f32, f32)] {
        &self.data
    }
}
impl std::ops::DerefMut for RfftVec {
    fn deref_mut(&mut self) -> &mut [(f32, f32)] {
        &mut self.data
    }
}
impl Drop for RfftVec {
    fn drop(&mut self) {
        memtrack::on_free(self.data.len() * 8, self.cat);
    }
}
impl Clone for RfftVec {
    fn clone(&self) -> Self {
        memtrack::on_alloc(self.data.len() * 8, self.cat);
        RfftVec { data: self.data.clone(), cat: self.cat }
    }
}

/// `torch.fft.rfft(x)`: allocate the `n+2`-real output, fill it with the
/// non-redundant half-spectrum. Requires a scratch copy of the input
/// because the output buffer cannot alias the input (dimension mismatch) —
/// exactly the pre-allocation problem FFTW/cuFFT document.
pub fn rfft_alloc(x: &[f32], cat: Category) -> RfftVec {
    let n = x.len();
    let plan = cached(n);
    // Scratch real buffer (the "cannot reuse the input" cost).
    let mut scratch = memtrack::TrackedVec::from_vec(x.to_vec(), cat);
    rdfft_inplace(&plan, &mut scratch);
    let mut out = RfftVec::zeros(n / 2 + 1, cat);
    for k in 0..=n / 2 {
        out[k] = layout::get(&scratch, k);
    }
    out
}

/// `torch.fft.irfft(spec)`: allocate the `n`-real output and inverse
/// transform into it.
pub fn irfft_alloc(spec: &RfftVec, cat: Category) -> memtrack::TrackedVec {
    let n = (spec.len() - 1) * 2;
    let plan = cached(n);
    let mut out = memtrack::TrackedVec::zeros(n, cat);
    layout::pack_from_rfft(spec, &mut out);
    irdfft_inplace(&plan, &mut out);
    out
}

/// Elementwise complex product of two rfft-format spectra, **allocating**
/// the result (as `a * b` on torch complex tensors does).
pub fn rfft_mul(a: &RfftVec, b: &RfftVec, cat: Category) -> RfftVec {
    assert_eq!(a.len(), b.len());
    let mut out = RfftVec::zeros(a.len(), cat);
    for k in 0..a.len() {
        let (ar, ai) = a[k];
        let (br, bi) = b[k];
        out[k] = (ar * br - ai * bi, ar * bi + ai * br);
    }
    out
}

/// Conjugate of an rfft-format spectrum, **allocating** (torch `.conj()`
/// is lazy but materializes on the next op; we charge it where PyTorch's
/// profiler sees it).
pub fn rfft_conj(a: &RfftVec, cat: Category) -> RfftVec {
    let mut out = RfftVec::zeros(a.len(), cat);
    for k in 0..a.len() {
        out[k] = (a[k].0, -a[k].1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::naive_dft;

    #[test]
    fn rfft_matches_naive_half_spectrum() {
        let x: Vec<f32> = (0..64).map(|i| ((i * 7 + 3) % 31) as f32 / 15.0 - 1.0).collect();
        let spec = rfft_alloc(&x, Category::Other);
        let want = naive_dft(&x);
        assert_eq!(spec.len(), 33);
        for k in 0..=32 {
            assert!((spec[k].0 - want[k].0).abs() < 1e-3, "k={k}");
            assert!((spec[k].1 - want[k].1).abs() < 1e-3, "k={k}");
        }
    }

    #[test]
    fn irfft_inverts_rfft() {
        let x: Vec<f32> = (0..128).map(|i| (i as f32 * 0.17).sin()).collect();
        let spec = rfft_alloc(&x, Category::Other);
        let back = irfft_alloc(&spec, Category::Other);
        for i in 0..128 {
            assert!((back[i] - x[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn output_occupies_n_plus_2_reals() {
        let x = vec![0.5f32; 256];
        let spec = rfft_alloc(&x, Category::Other);
        assert_eq!(spec.real_len(), 258);
    }

    #[test]
    fn allocation_profile_is_out_of_place() {
        memtrack::reset();
        let x = vec![1.0f32; 1024]; // untracked input (framework-owned)
        let spec = rfft_alloc(&x, Category::Intermediates);
        let snap = memtrack::snapshot();
        // scratch (4096 B) died inside rfft_alloc? No: it lives until the
        // function returns, so peak = scratch + output.
        assert_eq!(snap.current_total(), (1024 / 2 + 1) * 8);
        assert!(snap.peak_total >= 1024 * 4 + (1024 / 2 + 1) * 8);
        drop(spec);
        assert_eq!(memtrack::snapshot().current_total(), 0);
    }
}
