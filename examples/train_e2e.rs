//! End-to-end driver: train the adapted transformer through the full
//! three-layer stack.
//!
//! Rust (L3) drives a PJRT executable compiled from HLO that was lowered
//! once from the JAX model (L2) whose circulant adapters run the Pallas
//! rdFFT kernels (L1). Python is not involved at runtime.
//!
//! ```bash
//! make artifacts-e2e
//! cargo run --release --example train_e2e -- artifacts-e2e [steps]
//! ```
//!
//! Writes `train_e2e_loss.csv` and prints the loss curve; exits non-zero
//! if the loss fails to drop (so CI can gate on it).

use rdfft::coordinator::{Trainer, TrainerConfig};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let artifacts = PathBuf::from(args.first().map(String::as_str).unwrap_or("artifacts-e2e"));
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);

    println!("=== rdFFT end-to-end training ===");
    println!("artifacts: {}", artifacts.display());

    let cfg = TrainerConfig {
        steps,
        eval_every: (steps / 10).max(1),
        eval_batches: 4,
        corpus_bytes: 1 << 20,
        seed: 0,
        log_csv: Some(PathBuf::from("train_e2e_loss.csv")),
        checkpoint: Some(PathBuf::from("adapter_checkpoint.bin")),
    };
    let mut trainer = Trainer::new(&artifacts, cfg)?;
    let report = trainer.run()?;

    println!("\nloss curve (every ~{}th step):", (report.losses.len() / 20).max(1));
    let stride = (report.losses.len() / 20).max(1);
    for (step, loss) in report.losses.iter().step_by(stride) {
        let bar = "#".repeat(((loss / report.first_loss) * 40.0) as usize);
        println!("  step {step:>5}  {loss:.4}  {bar}");
    }

    println!(
        "\nfinal: {:.4} -> {:.4} ({} steps, {:.0} tok/s, eval {:.4})",
        report.first_loss,
        report.final_loss,
        report.steps,
        report.tokens_per_sec,
        report.final_eval_loss.unwrap_or(f32::NAN)
    );
    anyhow::ensure!(
        report.final_loss < report.first_loss * 0.9,
        "expected >=10% loss reduction, got {:.4} -> {:.4}",
        report.first_loss,
        report.final_loss
    );
    println!("train_e2e OK");
    Ok(())
}
