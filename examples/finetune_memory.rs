//! Fine-tuning memory walkthrough — the paper's §5.1.1 story on one
//! concrete configuration, with the breakdown printed per phase, followed
//! by the *multi-layer* Table-1-style rows measured on the pure-Rust
//! native training pipeline (no Python, no PJRT).
//!
//! ```bash
//! cargo run --release --example finetune_memory [-- D B p]
//! ```

use rdfft::autograd::layers::Backend;
use rdfft::autograd::train::{measure_single_layer_with_state, Method};
use rdfft::autograd::{CirculantLayer, Layer, Tensor};
use rdfft::memtrack::{self, Category, CATEGORIES};

fn main() {
    let args: Vec<usize> =
        std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let d = args.first().copied().unwrap_or(1024);
    let b = args.get(1).copied().unwrap_or(16);
    let p = args.get(2).copied().unwrap_or(128);

    println!("=== single-layer fine-tuning memory (D={d}, B={b}, p={p}) ===\n");

    // Phase-by-phase walkthrough for the rdFFT layer.
    println!("rdFFT layer, phase by phase:");
    memtrack::reset();
    let mut layer = CirculantLayer::new(Backend::RdFft, d, d, p, 1);
    let snap = memtrack::snapshot();
    println!(
        "  after construction: trainable={}B grads={}B other={}B",
        snap.current[Category::Trainable.index()],
        snap.current[Category::Gradients.index()],
        snap.current[Category::Other.index()],
    );
    let x = Tensor::rand(b, d, 1.0, 2, Category::Intermediates);
    memtrack::reset_peak();
    let y = layer.forward(x);
    let fwd = memtrack::snapshot();
    println!(
        "  forward: +{} allocations, intermediates now {}B (just the output tensor)",
        fwd.alloc_count,
        fwd.current[Category::Intermediates.index()],
    );
    let mut g = Tensor::zeros_cat(b, d, Category::Intermediates);
    g.fill(1.0);
    drop(y);
    memtrack::reset_peak();
    let dx = layer.backward(g);
    let bwd = memtrack::snapshot();
    println!("  backward: +{} allocations (grad_output overwritten in place)", bwd.alloc_count);
    // Release the walkthrough's tracked tensors before the measurement
    // loops below reset the tracker, so the accounting stays balanced in
    // debug builds.
    drop(dx);
    drop(layer);

    // Cross-method comparison.
    println!("\npeak memory, one fwd+bwd step (MiB):");
    println!("{:<16}{:>10}  breakdown at peak", "method", "peak");
    for m in [
        Method::FullFinetune,
        Method::Lora { rank: 32 },
        Method::Circulant { backend: Backend::Fft, p },
        Method::Circulant { backend: Backend::Rfft, p },
        Method::Circulant { backend: Backend::RdFft, p },
    ] {
        let cell = measure_single_layer_with_state(m, d, b, 1);
        let s = cell.snapshot;
        let parts: Vec<String> = CATEGORIES
            .iter()
            .filter(|c| s.at_peak[c.index()] > 0)
            .map(|c| {
                format!("{}={:.2}", c.name(), s.at_peak[c.index()] as f64 / (1024.0 * 1024.0))
            })
            .collect();
        println!("{:<16}{:>10.2}  {}", m.label(), cell.peak_mib(), parts.join(" "));
    }

    // Multi-layer rows: the same method axis measured end-to-end on the
    // native trainer (depth-2 residual stack, a few SGD steps) — the
    // Table-1-style rows for real multi-layer training, via the shared
    // experiments sweep.
    let depth = 2;
    let mp = p.min(d / 2).max(2);
    println!("\nmulti-layer native training (d={d}, depth={depth}, p={mp}, batch={b}):");
    rdfft::coordinator::experiments::native_method_rows(d, depth, b, 4, mp);
    println!("\nfinetune_memory OK");
}
