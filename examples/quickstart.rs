//! Quickstart: the rdFFT public API in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use rdfft::memtrack::{self, Category};
use rdfft::rdfft::{
    irdfft_inplace, layout, plan::cached, rdfft_inplace, spectral, BlockCirculant, Circulant,
};

fn main() {
    // ------------------------------------------------------------------
    // 1. A fully in-place transform: N reals -> N reals, same buffer.
    // ------------------------------------------------------------------
    let n = 16;
    let plan = cached(n);
    let mut buf: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).sin()).collect();
    let original = buf.clone();

    rdfft_inplace(&plan, &mut buf);
    println!("packed spectrum (same {n}-float buffer):");
    println!("  DC = {:.3}, Nyquist = {:.3}", buf[0], buf[n / 2]);
    for k in 1..4 {
        let (re, im) = layout::get(&buf, k);
        println!("  y_{k} = {re:.3} + {im:.3}i  (re at [{k}], im at [{}])", n - k);
    }

    irdfft_inplace(&plan, &mut buf);
    let max_err = buf
        .iter()
        .zip(&original)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("roundtrip max error: {max_err:.2e}\n");

    // ------------------------------------------------------------------
    // 2. Circulant matvec in the frequency domain (paper Eq. 4).
    // ------------------------------------------------------------------
    let c: Vec<f32> = (0..n).map(|i| 1.0 / (1.0 + i as f32)).collect();
    let circ = Circulant::from_first_column(&c);
    let mut x: Vec<f32> = (0..n).map(|i| (i % 3) as f32 - 1.0).collect();
    circ.matvec_inplace(&mut x); // x := C x, zero allocation
    println!("C·x (in place) first four: {:?}\n", &x[..4]);

    // ------------------------------------------------------------------
    // 3. A trainable block-circulant layer with Eq. 5 gradients.
    // ------------------------------------------------------------------
    let (rows, cols, p) = (32, 32, 8);
    let cols_init: Vec<f32> = (0..(rows / p) * (cols / p) * p)
        .map(|i| ((i * 7 + 3) % 11) as f32 / 11.0 - 0.5)
        .collect();
    let mut bc = BlockCirculant::from_block_columns(rows, cols, p, &cols_init);
    let mut input: Vec<f32> = (0..cols).map(|i| (i as f32 / 5.0).cos()).collect();
    let mut out = vec![0.0f32; rows];
    bc.forward_inplace(&mut input, &mut out); // input now holds x̂ (saved!)
    let mut g = vec![1.0f32; rows];
    let mut dx = vec![0.0f32; cols];
    let mut dc = vec![0.0f32; bc.num_params()];
    bc.backward(&input, &mut g, &mut dx, &mut dc);
    bc.sgd_step(&dc, 1e-2);
    println!("block-circulant layer: {} trainable params updated", bc.num_params());
    // The operator's parameter storage is memtrack-registered; release it
    // before the tracker reset below so the accounting stays balanced.
    drop(bc);

    // ------------------------------------------------------------------
    // 4. The memory story, measured (what Table 1 automates).
    // ------------------------------------------------------------------
    memtrack::reset();
    let sig: Vec<f32> = (0..1024).map(|i| (i as f32).sin()).collect();

    let before = memtrack::snapshot().alloc_count;
    let mut ours = sig.clone(); // one working buffer, owned by the caller
    let plan = cached(1024);
    memtrack::reset_peak();
    rdfft_inplace(&plan, &mut ours);
    let other = ours.clone(); // second spectrum (caller-owned, demo only)
    spectral::mul_inplace(&mut ours, &other);
    let ours_allocs = memtrack::snapshot().alloc_count;

    memtrack::reset();
    memtrack::reset_peak();
    let spec = rdfft::baselines::rfft::rfft_alloc(&sig, Category::Intermediates);
    let rfft_peak = memtrack::snapshot().peak_total;
    drop(spec);

    println!("\nrdFFT transform allocations: {} (beyond caller buffers)", ours_allocs - before);
    println!("rfft transform transient peak: {rfft_peak} bytes (out-of-place n+2 layout)");
    println!("\nquickstart OK");
}
