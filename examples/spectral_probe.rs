//! The paper's Limitations section, demonstrated: the packed layout is
//! implicit — using the spectrum *explicitly* (here: a low-pass filter)
//! requires decoding to complex form, which costs the allocation rdFFT
//! otherwise avoids. Also demos the bf16 path (the capability fft/rfft
//! libraries lack).
//!
//! ```bash
//! cargo run --release --example spectral_probe
//! ```

use rdfft::memtrack::{self, Category};
use rdfft::rdfft::bf16::{irdfft_inplace_bf16, rdfft_inplace_bf16, Bf16};
use rdfft::rdfft::{irdfft_inplace, layout, plan::cached, rdfft_inplace};

fn main() {
    let n = 256;
    let plan = cached(n);

    // A two-tone signal: slow (k=3) + fast (k=60) component.
    let sig: Vec<f32> = (0..n)
        .map(|i| {
            let t = i as f64 / n as f64;
            ((std::f64::consts::TAU * 3.0 * t).sin()
                + 0.5 * (std::f64::consts::TAU * 60.0 * t).sin()) as f32
        })
        .collect();

    // ------------------------------------------------------------------
    // 1. IMPLICIT spectral op (filtering by zeroing packed slots): still
    //    fully in place — both Re(y_k) (index k) and Im(y_k) (index n-k)
    //    are addressable without decoding.
    // ------------------------------------------------------------------
    memtrack::reset();
    let mut buf = sig.clone();
    rdfft_inplace(&plan, &mut buf);
    let cutoff = 20;
    for k in cutoff..=n / 2 {
        layout::set(&mut buf, k, 0.0, if k == n / 2 { 0.0 } else { 0.0 });
    }
    irdfft_inplace(&plan, &mut buf);
    println!(
        "in-place low-pass: allocations = {}, residual fast-tone energy = {:.2e}",
        memtrack::snapshot().alloc_count,
        tone_energy(&buf, 60)
    );
    println!("  slow-tone energy kept: {:.3} (want ~{:.3})", tone_energy(&buf, 3), tone_energy(&sig, 3));

    // ------------------------------------------------------------------
    // 2. EXPLICIT complex access (the limitation): decode to (re, im)
    //    pairs — costs an n+2-real allocation, exactly what the paper
    //    says you pay when you need the complex spectrum itself.
    // ------------------------------------------------------------------
    memtrack::reset();
    let mut buf2 = sig.clone();
    rdfft_inplace(&plan, &mut buf2);
    let decoded = {
        let _scope = memtrack::ScopedCategory::new(Category::Intermediates);
        let pairs = layout::unpack_rfft(&buf2); // allocates (untracked Vec)
        memtrack::on_alloc(pairs.len() * 8, Category::Intermediates); // account it
        pairs
    };
    let dominant = decoded
        .iter()
        .enumerate()
        .max_by(|a, b| mag(a.1).partial_cmp(&mag(b.1)).unwrap())
        .map(|(k, _)| k)
        .unwrap();
    println!(
        "\nexplicit complex decode: {} extra bytes; dominant bin = {dominant} (expect 3)",
        memtrack::snapshot().current_total()
    );
    memtrack::on_free(decoded.len() * 8, Category::Intermediates);

    // ------------------------------------------------------------------
    // 3. bf16 path: same transform on 2-byte storage.
    // ------------------------------------------------------------------
    let mut bbuf: Vec<Bf16> = sig.iter().map(|&v| Bf16::from_f32(v)).collect();
    rdfft_inplace_bf16(&plan, &mut bbuf);
    let bf_dc = bbuf[0].to_f32();
    irdfft_inplace_bf16(&plan, &mut bbuf);
    let max_err = bbuf
        .iter()
        .zip(&sig)
        .map(|(a, b)| (a.to_f32() - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "\nbf16 path: buffer is {} bytes (vs {} f32), DC={bf_dc:.3}, roundtrip max err {max_err:.3}",
        bbuf.len() * 2,
        sig.len() * 4
    );
    println!("\nspectral_probe OK");
}

fn mag(c: &(f32, f32)) -> f32 {
    (c.0 * c.0 + c.1 * c.1).sqrt()
}

/// Goertzel-style single-bin energy probe.
fn tone_energy(x: &[f32], k: usize) -> f32 {
    let n = x.len();
    let (mut re, mut im) = (0.0f64, 0.0f64);
    for (i, &v) in x.iter().enumerate() {
        let th = std::f64::consts::TAU * k as f64 * i as f64 / n as f64;
        re += v as f64 * th.cos();
        im -= v as f64 * th.sin();
    }
    ((re * re + im * im).sqrt() / n as f64) as f32
}
