//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this vendored crate
//! provides the (small) subset of `anyhow`'s API the repo actually uses:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Semantics match upstream where
//! it matters to callers:
//!
//! * `{err}` displays the outermost context,
//! * `{err:#}` displays the whole chain joined with `": "`,
//! * `{err:?}` displays the chain in the familiar `Caused by:` form,
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::fmt;

/// A context-carrying error. Index 0 of the chain is the outermost
/// context; the last entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The root-cause message (innermost of the chain).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`: that keeps
// this blanket `From` coherent next to core's identity `From<T> for T`
// (the same trick upstream anyhow uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context` — attach context to a `Result` or `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_and_alternate_shows_chain() {
        let e: Error = Err::<(), _>(io_err()).context("loading manifest.json").unwrap_err();
        assert_eq!(format!("{e}"), "loading manifest.json");
        let full = format!("{e:#}");
        assert!(full.contains("loading manifest.json"));
        assert!(full.contains("file missing"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn ensure_and_bail_work() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x > 2, "x too small: {x}");
            if x > 100 {
                bail!("x too big");
            }
            Ok(x)
        }
        assert!(f(1).is_err());
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(101).is_err());
    }

    #[test]
    fn with_context_is_lazy_and_chains() {
        let r: Result<()> = Err::<(), _>(io_err()).with_context(|| format!("step {}", 3));
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "step 3");
        assert!(format!("{e:?}").contains("Caused by:"));
    }
}
