#!/usr/bin/env python3
"""Append large-n known-answer cases to rust/tests/fixtures/golden_rdfft.json.

The original fixture (n in {4 .. 1024}) is preserved byte-for-byte; this
script only splices new cases (n in {16384, 65536} by default) before the
closing of the "cases" array, so re-running it is idempotent and the
small-n vectors never churn.

Oracle (independent of the Rust implementation, same contract as the
original cases): a pure-f64 naive DFT by direct O(n^2) summation with
*exact* angle reduction — the phase of term (k, t) is looked up as
w[(k*t) mod n] with the product/mod computed in int64, so no angle ever
loses precision to a large float argument. No FFT library is involved.

Inputs for the appended cases: MMIX LCG (state = state*6364136223846793005
+ 1442695040888963407 mod 2^64), per-case state seeded as
GOLDEN_SEED ^ n, sample = (((state >> 33) % 256) - 128) / 64 — exact
multiples of 1/64 in [-2, 2), so the decimal literals parse losslessly
into f32.

packed[] is the rdFFT packed layout (Re y_k at k, Im y_k at n-k,
DC/Nyquist at 0 and n/2); roundtrip[] is the f64 inverse DFT of packed
(equals input to f64 precision). Values are written with %.8g — 8
significant digits, ~2x what an f32 comparison can resolve, keeping the
large-n fixture a few MB instead of tens.
"""

import sys

import numpy as np

GOLDEN_SEED = 20260731
NEW_SIZES = (16384, 65536)
FIXTURE = "rust/tests/fixtures/golden_rdfft.json"
LCG_MUL = 6364136223846793005
LCG_ADD = 1442695040888963407
MASK64 = (1 << 64) - 1


def lcg_input(n: int) -> np.ndarray:
    state = (GOLDEN_SEED ^ n) & MASK64
    out = np.empty(n, dtype=np.float64)
    for i in range(n):
        state = (state * LCG_MUL + LCG_ADD) & MASK64
        out[i] = (((state >> 33) % 256) - 128) / 64.0
    return out


def naive_dft(x: np.ndarray, inverse: bool = False, chunk: int = 64) -> np.ndarray:
    """Direct-summation DFT with exact int64 (k*t) mod n phase indexing."""
    n = len(x)
    sign = 2j if inverse else -2j
    w = np.exp(sign * np.pi * np.arange(n) / n)  # w[j] = e^(sign*pi*j/n*... )
    t = np.arange(n, dtype=np.int64)
    y = np.empty(n, dtype=np.complex128)
    for k0 in range(0, n, chunk):
        k = np.arange(k0, min(k0 + chunk, n), dtype=np.int64)
        idx = (k[:, None] * t[None, :]) % n
        y[k0 : k0 + len(k)] = w[idx] @ x
    return y


def pack(y: np.ndarray) -> np.ndarray:
    n = len(y)
    p = np.empty(n, dtype=np.float64)
    p[0] = y[0].real
    p[n // 2] = y[n // 2].real
    for k in range(1, n // 2):
        p[k] = y[k].real
        p[n - k] = y[k].imag
    return p


def unpack(p: np.ndarray) -> np.ndarray:
    n = len(p)
    y = np.empty(n, dtype=np.complex128)
    y[0] = p[0]
    y[n // 2] = p[n // 2]
    for k in range(1, n // 2):
        y[k] = p[k] + 1j * p[n - k]
        y[n - k] = p[k] - 1j * p[n - k]
    return y


def fmt(v: float) -> str:
    return "%.8g" % v


def case_text(n: int) -> str:
    print(f"generating n={n} ...", flush=True)
    x = lcg_input(n)
    y = naive_dft(x)
    packed = pack(y)
    rt = naive_dft(unpack(packed), inverse=True).real / n
    err = np.max(np.abs(rt - x))
    assert err < 1e-9, f"oracle roundtrip drifted: {err}"
    lines = ["  {", f'   "n": {n},']
    for name, vals in (("input", x), ("packed", packed), ("roundtrip", rt)):
        lines.append(f'   "{name}": [')
        body = ",\n".join(f"    {fmt(v)}" for v in vals)
        lines.append(body)
        lines.append("   ]," if name != "roundtrip" else "   ]")
    lines.append("  }")
    return "\n".join(lines)


def main() -> int:
    with open(FIXTURE, "r", encoding="ascii") as f:
        text = f.read()
    tail = "\n ]\n}\n"
    if not text.endswith(tail):
        print("fixture tail not in expected format; refusing to splice", file=sys.stderr)
        return 1
    added = []
    for n in NEW_SIZES:
        if f'"n": {n},' in text:
            print(f"n={n} already present; skipping")
            continue
        block = case_text(n)
        text = text[: -len(tail)] + ",\n" + block + tail
        added.append(n)
    with open(FIXTURE, "w", encoding="ascii") as f:
        f.write(text)
    print(f"appended {added or 'nothing'} -> {FIXTURE}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
