#!/usr/bin/env bash
# Tier-1 gate as one command: build (all targets, so benches/examples
# stay compiling), test, a native-trainer smoke run, the engine bench
# grid (machine-readable BENCH_rdfft.json), and — when rustfmt is
# installed — format check.
#
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release --all-targets

REPRO=./target/release/repro
if [[ ! -x "$REPRO" ]]; then
  echo "ci.sh: ERROR: $REPRO is missing or not executable after a release build." >&2
  echo "       The binary target is named 'repro' (rust/Cargo.toml [[bin]]); if it" >&2
  echo "       was renamed, update this script and .github/workflows/ci.yml." >&2
  exit 1
fi

# Static invariant audit: hard gate, and it runs BEFORE the test suite —
# an unsafe block without a SAFETY comment or a raw `.lock().unwrap()`
# must fail the build even when every test is green. Writes AUDIT.json
# (schema audit/v1: findings + every allow-waiver with its reason) for
# the workflow to upload. EXPERIMENTS.md §Audit documents the lints.
"$REPRO" audit --json AUDIT.json
if [[ ! -s AUDIT.json ]]; then
  echo "ci.sh: ERROR: repro audit did not produce AUDIT.json" >&2
  exit 1
fi

# Tests stay on the dev profile deliberately: the engine/layer guards are
# debug_assert-based and a --release test run would compile them away
# (the dev build is the only extra profile — the smoke and bench runs
# below reuse the release artifacts already built, no third build).
cargo test -q

# Native-trainer smoke: 20 steps on a depth-2 circulant stack must reduce
# the loss AND keep the memtrack peak under a fixed budget (the binary
# exits non-zero on either failure).
"$REPRO" train-native \
  --steps 20 --d 64 --depth 2 --p 16 --batch 8 --eval-every 10 \
  --max-peak-mib 8

# Data-parallel smoke: the same run through the worker-pool sharded step
# (--threads 2), with the same loss gate and peak budget. The budget is
# unchanged on purpose: the pooled grad-shard arena plus worker-merged
# activation scratch must stay within the serial envelope at this scale.
"$REPRO" train-native \
  --steps 20 --d 64 --depth 2 --p 16 --batch 8 --eval-every 10 \
  --threads 2 --max-peak-mib 8

# Long-conv smoke: the same 20-step gate on the heterogeneous tower
# (--layer mixed = circulant blocks + a long-conv top block), sharded,
# with the same loss-trend gate and a fixed memory budget. The long-conv
# block's kernel spectrum is FFT'd once per step and applied per row by
# the fused sweep — this run is the end-to-end proof that the layer
# trains inside the full stack, not just in unit tests.
"$REPRO" train-native \
  --steps 20 --d 64 --depth 2 --layer mixed --p 16 --k 16 --batch 8 \
  --eval-every 10 --threads 2 --max-peak-mib 8

# Crash-safety smoke: train → kill (abort / torn checkpoint write /
# worker-pool panic) → resume, asserting the resumed loss and parameter
# trajectories are bit-identical to an uninterrupted run, that torn and
# corrupted checkpoints are detected and skipped, and that a foreign
# config's checkpoints are refused. The log is uploaded as a CI artifact
# (pipefail is set above, so the tee does not mask a failure).
"$REPRO" crashtest 2>&1 | tee crashtest.log

# Four-step smoke: correctness-only sweep of the large-n (Bailey) tier
# against the direct stage sweep plus a roundtrip check, no timing. The
# workflow matrix runs this script on both dispatch legs, so the smoke
# covers the SIMD arms here and the forced-scalar tier under
# RDFFT_FORCE_SCALAR=1 on the other leg.
"$REPRO" engine --fourstep-smoke

# Engine grid: writes BENCH_rdfft.json (schema bench_rdfft/v3 —
# fused/unfused circulant rows, the pool thread grid, the batch_simd /
# circulant_fused_simd rows with the simd_vs_scalar gate, the
# batch_simd8-vs-batch_simd4 width-tier pair, the longconv_fused /
# longconv_unfused pair with the longconv_fused_vs_unfused gate, and the
# batch_fourstep-vs-batch_direct large-n grid with the fourstep_vs_direct
# gate plus per-cell fourstep_tier_engaged telemetry gates — a
# "fourstep" cell that silently ran the direct sweep hard-fails as
# mismeasured) and exits non-zero if a hard gate regresses. The workflow
# uploads the JSON next to the loss-curve CSV.
"$REPRO" engine --fast
if [[ ! -s BENCH_rdfft.json ]]; then
  echo "ci.sh: ERROR: repro engine did not produce BENCH_rdfft.json" >&2
  exit 1
fi
# The committed file is a placeholder with an empty records array (no
# toolchain in the authoring container); a measured run must have
# replaced it. Catch the silent-no-op failure mode where the bench ran
# but recorded nothing.
if grep -q '"records": \[\]' BENCH_rdfft.json; then
  echo "ci.sh: ERROR: BENCH_rdfft.json still matches the committed placeholder" >&2
  echo "       (empty records array) — repro engine recorded no measurements." >&2
  exit 1
fi

# Serving smoke: the slam harness drives the micro-batching server with
# concurrent clients and enforces its hard gates in-process — every
# request answered, responses bit-identical across arrival orders and
# thread counts, zero steady-state tracked allocations, the coalescing
# ratio above the clear-regression floor, and (here) a generous p99
# sanity budget. clients >= window so the closed-loop leg (periodic
# flusher racing submit_next) runs in CI too. Writes BENCH_serve.json
# (p50/p99 + tokens/sec rows and the coalesce_vs_single gate), uploaded
# next to BENCH_rdfft.json.
"$REPRO" slam \
  --requests 192 --window 8 --clients 8 --threads 2 --rounds 2 \
  --bench BENCH_serve.json --max-p99-ms 500
if [[ ! -s BENCH_serve.json ]]; then
  echo "ci.sh: ERROR: repro slam did not produce BENCH_serve.json" >&2
  exit 1
fi
# Same placeholder-detection pattern as BENCH_rdfft.json: the committed
# file has an empty records array; a measured run must have replaced it.
if grep -q '"records": \[\]' BENCH_serve.json; then
  echo "ci.sh: ERROR: BENCH_serve.json still matches the committed placeholder" >&2
  echo "       (empty records array) — repro slam recorded no measurements." >&2
  exit 1
fi

# Format check is advisory: the tree is hand-formatted and the tier-1
# gate is build+test+smoke; a rustfmt drift warning must not mask a
# green functional run.
if command -v rustfmt >/dev/null 2>&1; then
  cargo fmt --all --check \
    || echo "ci.sh: WARNING: rustfmt reports formatting drift (advisory only)" >&2
else
  echo "ci.sh: rustfmt not installed; skipping format check" >&2
fi

echo "ci.sh: OK"
