#!/usr/bin/env bash
# Tier-1 gate as one command: build (all targets, so benches/examples
# stay compiling), test, and — when rustfmt is installed — format check.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release --all-targets
cargo test -q

if command -v rustfmt >/dev/null 2>&1; then
  cargo fmt --all --check
else
  echo "ci.sh: rustfmt not installed; skipping format check" >&2
fi

echo "ci.sh: OK"
