#!/usr/bin/env bash
# Tier-1 gate as one command: build (all targets, so benches/examples
# stay compiling), test (unit + integration + differential + native
# training suites), a native-trainer smoke run, and — when rustfmt is
# installed — format check.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release --all-targets
cargo test -q

# Native-trainer smoke: 20 steps on a depth-2 circulant stack must reduce
# the loss AND keep the memtrack peak under a fixed budget (the binary
# exits non-zero on either failure).
./target/release/repro train-native \
  --steps 20 --d 64 --depth 2 --p 16 --batch 8 --eval-every 10 \
  --max-peak-mib 8

if command -v rustfmt >/dev/null 2>&1; then
  cargo fmt --all --check
else
  echo "ci.sh: rustfmt not installed; skipping format check" >&2
fi

echo "ci.sh: OK"
